"""Pallas paged flash-decode / flash-verify: block-table-indexed KV pools.

The paged siblings of ``decode.py`` / ``verify.py``: K/V live in a global
pool of fixed-size pages shared by every slot and a (B, max_pages) int32
block table maps a slot's logical page p to its physical pool page (or -1
when unallocated).  The grid is (B, KV_heads, max_pages) — every program
owns one (batch, kv-head) pair and ONE logical page of that slot's cache —
and the page's physical K/V tile is fetched by the BlockSpec ``index_map``
reading the block table from the scalar-prefetch operand.  That is the
whole trick: the DMA engine walks the page table, so the slot's logically
contiguous cache is never gathered into a contiguous buffer (the XLA
fallback in ``repro.models.attention`` does gather — it exists for
correctness on non-TPU backends, not for memory).

Everything else matches the dense kernels: online softmax over ``block_k``
tiles inside the page, tile-wise int8 dequant in VMEM, skipped
out-of-range/unallocated pages, unnormalized (acc, m, l) partials merged by
a logsumexp combine in the wrapper.  Per-page partials play the role the
split-K partials play in the dense kernels — the split factor is simply the
page count, so decode latency scales with ``cache_len / page_size`` pages
of parallel work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common
from repro.kernels.common import PagedDecodeConfig, PagedVerifyConfig

NEG_INF = -1e30


def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, *rest,
                         block_k, page_size, scale, cap, window, quantized):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref = rest
    else:
        o_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    p = pl.program_id(2)
    length = len_ref[b]
    page = bt_ref[b, p]
    k_lo = p * page_size                    # logical row of the page's row 0
    g, d = q_ref.shape[2], q_ref.shape[3]

    needed = jnp.logical_and(k_lo < length, page >= 0)
    if window and window > 0:
        needed = jnp.logical_and(needed,
                                 k_lo + page_size > length - window)

    @pl.when(jnp.logical_not(needed))
    def _skip():
        o_ref[0, 0, 0] = jnp.zeros_like(o_ref[0, 0, 0])
        m_ref[0, 0, 0] = jnp.full_like(m_ref[0, 0, 0], NEG_INF)
        l_ref[0, 0, 0] = jnp.zeros_like(l_ref[0, 0, 0])

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                     # (G, D)

        def body(i, carry):
            m, l, acc = carry
            rows = pl.ds(i * block_k, block_k)
            kb = k_ref[0, rows, 0, :].astype(jnp.float32)       # (bk, D)
            vb = v_ref[0, rows, 0, :].astype(jnp.float32)
            if quantized:
                kb = kb * ks_ref[0, rows, 0][:, None]
                vb = vb * vs_ref[0, rows, 0][:, None]
            x = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ()))) * scale
            if cap and cap > 0:
                x = cap * jnp.tanh(x / cap)
            kpos = k_lo + i * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (g, block_k), 1)
            valid = kpos < length
            if window and window > 0:
                valid = jnp.logical_and(valid, kpos >= length - window)
            x = jnp.where(valid, x, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(x, axis=-1, keepdims=True))
            m_safe = jnp.maximum(m_new, -0.5e30)
            pr = jnp.exp(x - m_safe)
            corr = jnp.exp(jnp.maximum(m, -0.5e30) - m_safe)
            l_new = l * corr + jnp.sum(pr, axis=-1, keepdims=True)
            acc_new = acc * corr + jax.lax.dot_general(
                pr, vb, (((1,), (0,)), ((), ())))
            return m_new, l_new, acc_new

        init = (jnp.full((g, 1), NEG_INF, jnp.float32),
                jnp.zeros((g, 1), jnp.float32),
                jnp.zeros((g, d), jnp.float32))
        m, l, acc = jax.lax.fori_loop(0, page_size // block_k, body, init)
        o_ref[0, 0, 0] = acc
        m_ref[0, 0, 0] = m[:, 0]
        l_ref[0, 0, 0] = l[:, 0]


def _paged_verify_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, *rest,
                         block_k, page_size, gq, scale, cap, window,
                         quantized):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref = rest
    else:
        o_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    p = pl.program_id(2)
    length = len_ref[b]                  # committed rows BEFORE the verify
    page = bt_ref[b, p]
    k_lo = p * page_size
    rows, d = q_ref.shape[2], q_ref.shape[3]           # rows == S * G
    n_pos = rows // gq

    # the deepest query (position n_pos - 1) sees rows < length + n_pos
    needed = jnp.logical_and(k_lo < length + n_pos, page >= 0)
    if window and window > 0:
        needed = jnp.logical_and(needed,
                                 k_lo + page_size > length + 1 - window)

    @pl.when(jnp.logical_not(needed))
    def _skip():
        o_ref[0, 0, 0] = jnp.zeros_like(o_ref[0, 0, 0])
        m_ref[0, 0, 0] = jnp.full_like(m_ref[0, 0, 0], NEG_INF)
        l_ref[0, 0, 0] = jnp.zeros_like(l_ref[0, 0, 0])

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # (S*G, D)
        pos_of_row = jax.lax.broadcasted_iota(
            jnp.int32, (rows, block_k), 0) // gq

        def body(i, carry):
            m, l, acc = carry
            krows = pl.ds(i * block_k, block_k)
            kb = k_ref[0, krows, 0, :].astype(jnp.float32)  # (bk, D)
            vb = v_ref[0, krows, 0, :].astype(jnp.float32)
            if quantized:
                kb = kb * ks_ref[0, krows, 0][:, None]
                vb = vb * vs_ref[0, krows, 0][:, None]
            x = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ()))) * scale
            if cap and cap > 0:
                x = cap * jnp.tanh(x / cap)
            kpos = k_lo + i * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (rows, block_k), 1)
            # staircase causality: position s sees kpos <= length + s
            valid = kpos < length + pos_of_row + 1
            if window and window > 0:
                valid = jnp.logical_and(
                    valid, kpos > length + pos_of_row - window)
            x = jnp.where(valid, x, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(x, axis=-1, keepdims=True))
            m_safe = jnp.maximum(m_new, -0.5e30)
            pr = jnp.exp(x - m_safe)
            corr = jnp.exp(jnp.maximum(m, -0.5e30) - m_safe)
            l_new = l * corr + jnp.sum(pr, axis=-1, keepdims=True)
            acc_new = acc * corr + jax.lax.dot_general(
                pr, vb, (((1,), (0,)), ((), ())))
            return m_new, l_new, acc_new

        init = (jnp.full((rows, 1), NEG_INF, jnp.float32),
                jnp.zeros((rows, 1), jnp.float32),
                jnp.zeros((rows, d), jnp.float32))
        m, l, acc = jax.lax.fori_loop(0, page_size // block_k, body, init)
        o_ref[0, 0, 0] = acc
        m_ref[0, 0, 0] = m[:, 0]
        l_ref[0, 0, 0] = l[:, 0]


def _combine(o_part, m_part, l_part, dtype):
    """Logsumexp merge of per-page partials (page axis == 2)."""
    m = jnp.maximum(jnp.max(m_part, axis=2, keepdims=True), -0.5e30)
    w = jnp.exp(jnp.maximum(m_part, -0.5e30) - m)
    denom = jnp.sum(l_part * w, axis=2)
    out = jnp.sum(o_part * w[..., None], axis=2)
    out = out / jnp.maximum(denom, 1e-30)[..., None]
    return out.astype(dtype)


def _page_pools(k, v, k_scale, v_scale, page_size):
    """Reshape flat (pool_rows, KV, D) pools to (P, page_size, KV, D)."""
    rows, kv, d = k.shape
    assert rows % page_size == 0, (rows, page_size)
    n = rows // page_size
    k = k.reshape(n, page_size, kv, d)
    v = v.reshape(n, page_size, kv, d)
    if k_scale is not None:
        k_scale = k_scale.reshape(n, page_size, kv).astype(jnp.float32)
        v_scale = v_scale.reshape(n, page_size, kv).astype(jnp.float32)
    return n, k, v, k_scale, v_scale


def paged_flash_decode(q, k, v, block_table, lengths, page_size,
                       k_scale=None, v_scale=None,
                       cfg: PagedDecodeConfig = None, *, cap: float = 0.0,
                       window: int = 0, interpret: bool = False,
                       scale: float = None):
    """q: (B, KV, G, D); k/v: (pool_rows, KV, D) paged pools [int8 or float];
    block_table: (B, max_pages) int32 (-1 = unallocated); lengths: (B,) int32
    valid LOGICAL cache length per slot INCLUDING the current token;
    k_scale/v_scale: (pool_rows, KV) dequant scales (required iff int8).

    Returns (B, KV, G, D) in q.dtype.
    """
    cfg = cfg or PagedDecodeConfig()
    b, kv, g, d = q.shape
    scale = d ** -0.5 if scale is None else float(scale)
    quantized = k_scale is not None
    if k_scale is not None and k_scale.ndim == 3:
        k_scale, v_scale = k_scale[..., 0], v_scale[..., 0]
    _, k, v, k_scale, v_scale = _page_pools(k, v, k_scale, v_scale, page_size)
    n_pages = block_table.shape[1]
    bk = min(cfg.block_k, page_size)
    assert page_size % bk == 0, (page_size, bk)

    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (b,))
    block_table = jnp.asarray(block_table, jnp.int32)

    def kv_map(bi, h, p, bt_ref, *_refs):
        # the DMA walks the page table: physical page (clamped so that even
        # an unallocated page DMAs a real tile — the kernel masks it)
        return (jnp.maximum(bt_ref[bi, p], 0), 0, h, 0)

    kv_spec = pl.BlockSpec((1, page_size, 1, d), kv_map)
    in_specs = [
        pl.BlockSpec((1, 1, g, d), lambda bi, h, p, *_refs: (bi, h, 0, 0)),
        kv_spec, kv_spec,
    ]
    args = [q, k, v]
    if quantized:
        sc_spec = pl.BlockSpec((1, page_size, 1),
                               lambda bi, h, p, bt_ref, *_refs:
                               (jnp.maximum(bt_ref[bi, p], 0), 0, h))
        in_specs += [sc_spec, sc_spec]
        args += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kv, n_pages),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, 1, g, d),
                         lambda bi, h, p, *_refs: (bi, h, p, 0, 0)),
            pl.BlockSpec((1, 1, 1, g), lambda bi, h, p, *_refs: (bi, h, p, 0)),
            pl.BlockSpec((1, 1, 1, g), lambda bi, h, p, *_refs: (bi, h, p, 0)),
        ],
    )
    o_part, m_part, l_part = pl.pallas_call(
        functools.partial(_paged_decode_kernel, block_k=bk,
                          page_size=page_size, scale=scale, cap=cap,
                          window=window, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, kv, n_pages, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, n_pages, g), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, n_pages, g), jnp.float32),
        ],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(block_table, lengths, *args)
    return _combine(o_part, m_part, l_part, q.dtype)


def paged_flash_verify(q, k, v, block_table, lengths, page_size, gq,
                       k_scale=None, v_scale=None,
                       cfg: PagedVerifyConfig = None, *, cap: float = 0.0,
                       window: int = 0, interpret: bool = False,
                       scale: float = None):
    """q: (B, KV, S*G, D) — S draft positions x G grouped query heads,
    position-major (row r = position r // G); k/v: (pool_rows, KV, D) paged
    pools with the S new rows already scattered at logical rows
    [lengths[b], lengths[b] + S); block_table: (B, max_pages) int32;
    lengths: (B,) committed LOGICAL rows per slot BEFORE the verify; gq: G.

    Returns (B, KV, S*G, D) in q.dtype.
    """
    cfg = cfg or PagedVerifyConfig()
    b, kv, rows, d = q.shape
    assert rows % gq == 0, (rows, gq)
    scale = d ** -0.5 if scale is None else float(scale)
    quantized = k_scale is not None
    if k_scale is not None and k_scale.ndim == 3:
        k_scale, v_scale = k_scale[..., 0], v_scale[..., 0]
    _, k, v, k_scale, v_scale = _page_pools(k, v, k_scale, v_scale, page_size)
    n_pages = block_table.shape[1]
    bk = min(cfg.block_k, page_size)
    assert page_size % bk == 0, (page_size, bk)

    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (b,))
    block_table = jnp.asarray(block_table, jnp.int32)

    def kv_map(bi, h, p, bt_ref, *_refs):
        return (jnp.maximum(bt_ref[bi, p], 0), 0, h, 0)

    kv_spec = pl.BlockSpec((1, page_size, 1, d), kv_map)
    in_specs = [
        pl.BlockSpec((1, 1, rows, d),
                     lambda bi, h, p, *_refs: (bi, h, 0, 0)),
        kv_spec, kv_spec,
    ]
    args = [q, k, v]
    if quantized:
        sc_spec = pl.BlockSpec((1, page_size, 1),
                               lambda bi, h, p, bt_ref, *_refs:
                               (jnp.maximum(bt_ref[bi, p], 0), 0, h))
        in_specs += [sc_spec, sc_spec]
        args += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kv, n_pages),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, 1, rows, d),
                         lambda bi, h, p, *_refs: (bi, h, p, 0, 0)),
            pl.BlockSpec((1, 1, 1, rows),
                         lambda bi, h, p, *_refs: (bi, h, p, 0)),
            pl.BlockSpec((1, 1, 1, rows),
                         lambda bi, h, p, *_refs: (bi, h, p, 0)),
        ],
    )
    o_part, m_part, l_part = pl.pallas_call(
        functools.partial(_paged_verify_kernel, block_k=bk,
                          page_size=page_size, gq=gq, scale=scale,
                          cap=cap, window=window, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, kv, n_pages, rows, d), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, n_pages, rows), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, n_pages, rows), jnp.float32),
        ],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(block_table, lengths, *args)
    return _combine(o_part, m_part, l_part, q.dtype)
