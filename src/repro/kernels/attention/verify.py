"""Pallas flash-verify: multi-position speculative-verify attention.

The short-query (q_len = spec_len + 1) sibling of the flash-decode kernel:
every slot scores its last emitted token plus ``spec_len`` draft tokens
against the shared KV cache in ONE pass.  The grid is (B, KV_heads,
k_splits) exactly like decode — each program owns one (batch, kv-head) pair
and one contiguous split of the cache — but the query block carries S * G
rows (S draft positions x G grouped query heads) instead of G, so the loaded
K/V tiles amortize over every draft position as well as every query head of
the group.

Causality across draft positions is a *staircase* mask: query position s
(rows [s*G, (s+1)*G) of the block) sees cache rows [0, lens[b] + s], i.e.
the slot's committed prefix plus the draft tokens before it (their K/V rows
are already scattered into the cache by ``transformer.verify_step``; rows
for later drafts sit beyond the visible length).  Everything else — online
softmax over ``block_k`` tiles, tile-wise int8 dequant in VMEM, skipped
out-of-range splits, the unnormalized (acc, m, l) partials merged by a
logsumexp combine in the wrapper — matches the decode kernel, and decode is
the S == 1 special case.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common
from repro.kernels.common import VerifyAttentionConfig, round_up

NEG_INF = -1e30


def _verify_kernel(len_ref, q_ref, k_ref, v_ref, *rest,
                   block_k, split_len, gq, scale, cap, window, quantized):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref = rest
    else:
        o_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    s = pl.program_id(2)
    length = len_ref[b]                      # committed rows BEFORE the verify
    k_lo = s * split_len
    rows, d = q_ref.shape[2], q_ref.shape[3]           # rows == S * G
    n_pos = rows // gq

    # the deepest query (position n_pos - 1) sees rows < length + n_pos; the
    # shallowest (position 0) sees rows >= length + 1 - window
    needed = k_lo < length + n_pos
    if window and window > 0:
        needed = jnp.logical_and(needed,
                                 k_lo + split_len > length + 1 - window)

    @pl.when(jnp.logical_not(needed))
    def _skip():
        o_ref[0, 0, 0] = jnp.zeros_like(o_ref[0, 0, 0])
        m_ref[0, 0, 0] = jnp.full_like(m_ref[0, 0, 0], NEG_INF)
        l_ref[0, 0, 0] = jnp.zeros_like(l_ref[0, 0, 0])

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # (S*G, D)
        # query row r belongs to draft position r // G
        pos_of_row = jax.lax.broadcasted_iota(
            jnp.int32, (rows, block_k), 0) // gq

        def body(i, carry):
            m, l, acc = carry                               # (SG,1) (SG,1) (SG,D)
            krows = pl.ds(i * block_k, block_k)
            kb = k_ref[0, krows, 0, :].astype(jnp.float32)  # (bk, D)
            vb = v_ref[0, krows, 0, :].astype(jnp.float32)
            if quantized:
                kb = kb * ks_ref[0, krows, 0][:, None]
                vb = vb * vs_ref[0, krows, 0][:, None]
            x = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ()))) * scale
            if cap and cap > 0:
                x = cap * jnp.tanh(x / cap)                 # (SG, bk)
            kpos = k_lo + i * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (rows, block_k), 1)
            # staircase causality: position s sees kpos <= length + s
            valid = kpos < length + pos_of_row + 1
            if window and window > 0:
                valid = jnp.logical_and(
                    valid, kpos > length + pos_of_row - window)
            x = jnp.where(valid, x, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(x, axis=-1, keepdims=True))
            m_safe = jnp.maximum(m_new, -0.5e30)
            p = jnp.exp(x - m_safe)
            corr = jnp.exp(jnp.maximum(m, -0.5e30) - m_safe)
            l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * corr + jax.lax.dot_general(
                p, vb, (((1,), (0,)), ((), ())))
            return m_new, l_new, acc_new

        init = (jnp.full((rows, 1), NEG_INF, jnp.float32),
                jnp.zeros((rows, 1), jnp.float32),
                jnp.zeros((rows, d), jnp.float32))
        m, l, acc = jax.lax.fori_loop(0, split_len // block_k, body, init)
        o_ref[0, 0, 0] = acc
        m_ref[0, 0, 0] = m[:, 0]
        l_ref[0, 0, 0] = l[:, 0]


def flash_verify(q, k, v, lengths, gq, k_scale=None, v_scale=None,
                 cfg: VerifyAttentionConfig = None, *, cap: float = 0.0,
                 window: int = 0, interpret: bool = False,
                 scale: float = None):
    """q: (B, KV, S*G, D) — S draft positions x G grouped query heads per
    kv-head, flattened position-major (row r = position r // G, head
    r % G); k/v: (B, T, KV, D) [int8 or float] with the S new rows already
    written at rows [lengths[b], lengths[b] + S); lengths: (B,) committed
    rows per slot BEFORE the verify; gq: G (query heads per kv-head);
    k_scale/v_scale: (B, T, KV) f32 per-(token, head) dequant scales
    (required iff k/v are int8).

    Returns (B, KV, S*G, D) in q.dtype.
    """
    cfg = cfg or VerifyAttentionConfig()
    b, kv, rows, d = q.shape
    assert rows % gq == 0, (rows, gq)
    t = k.shape[1]
    scale = d ** -0.5 if scale is None else float(scale)
    quantized = k_scale is not None

    bk = min(cfg.block_k, round_up(t, common.SUBLANE))
    split_len = round_up(-(-round_up(t, bk) // cfg.k_splits), bk)
    splits = -(-round_up(t, bk) // split_len)
    t_pad = split_len * splits
    if t_pad != t:
        pad = [(0, 0), (0, t_pad - t), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        if quantized:
            k_scale = jnp.pad(k_scale, pad[:3])
            v_scale = jnp.pad(v_scale, pad[:3])

    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (b,))

    kv_spec = pl.BlockSpec((1, split_len, 1, d),
                           lambda bi, h, s, *_refs: (bi, s, h, 0))
    in_specs = [
        pl.BlockSpec((1, 1, rows, d), lambda bi, h, s, *_refs: (bi, h, 0, 0)),
        kv_spec, kv_spec,
    ]
    args = [q, k, v]
    if quantized:
        sc_spec = pl.BlockSpec((1, split_len, 1),
                               lambda bi, h, s, *_refs: (bi, s, h))
        in_specs += [sc_spec, sc_spec]
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kv, splits),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, 1, rows, d),
                         lambda bi, h, s, *_refs: (bi, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, rows),
                         lambda bi, h, s, *_refs: (bi, h, s, 0)),
            pl.BlockSpec((1, 1, 1, rows),
                         lambda bi, h, s, *_refs: (bi, h, s, 0)),
        ],
    )
    o_part, m_part, l_part = pl.pallas_call(
        functools.partial(_verify_kernel, block_k=bk, split_len=split_len,
                          gq=gq, scale=scale, cap=cap, window=window,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, kv, splits, rows, d), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, splits, rows), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, splits, rows), jnp.float32),
        ],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(lengths, *args)

    # split-K combine: renormalize each partial to the global running max
    m = jnp.maximum(jnp.max(m_part, axis=2, keepdims=True), -0.5e30)
    w = jnp.exp(jnp.maximum(m_part, -0.5e30) - m)               # (B,KV,S,SG)
    denom = jnp.sum(l_part * w, axis=2)                          # (B,KV,SG)
    out = jnp.sum(o_part * w[..., None], axis=2)                 # (B,KV,SG,D)
    out = out / jnp.maximum(denom, 1e-30)[..., None]
    return out.astype(q.dtype)
