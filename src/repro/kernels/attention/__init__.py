from repro.kernels.attention import decode, kernel, ops, ref

__all__ = ["decode", "kernel", "ops", "ref"]
