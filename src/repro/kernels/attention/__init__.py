from repro.kernels.attention import decode, kernel, ops, ref, verify

__all__ = ["decode", "kernel", "ops", "ref", "verify"]
