from repro.kernels.attention import decode, kernel, ops, paged, ref, verify

__all__ = ["decode", "kernel", "ops", "paged", "ref", "verify"]
