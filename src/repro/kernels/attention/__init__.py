from repro.kernels.attention import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
