"""Kernel registry: names, tunable search spaces, and ref/impl bindings.

This is the deployment half of HAQA's joint search space — the TPU analogue
of the paper's per-kernel execution configuration (Appendix D "End-to-end
deployment search").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

from repro.kernels.common import (
    AttentionConfig, DecodeAttentionConfig, EltwiseConfig, MatmulConfig,
    PagedDecodeConfig, PagedVerifyConfig, RopeConfig, RowBlockConfig,
    VerifyAttentionConfig,
)


@dataclasses.dataclass(frozen=True)
class KernelInfo:
    name: str
    config_cls: type
    # tunable field -> candidate values (hardware-aligned)
    space: Dict[str, Tuple]
    paper_table3: bool          # appears in the paper's Table 3


KERNELS: Dict[str, KernelInfo] = {
    "matmul": KernelInfo(
        "matmul", MatmulConfig,
        space={
            "bm": (64, 128, 256, 512),
            "bn": (128, 256, 512, 1024),
            "bk": (128, 256, 512, 1024, 2048),
            "dimension_semantics": (
                ("parallel", "parallel", "arbitrary"),
                ("arbitrary", "arbitrary", "arbitrary"),
            ),
        },
        paper_table3=True),
    "softmax": KernelInfo(
        "softmax", RowBlockConfig,
        space={"block_rows": (8, 16, 32, 64, 128, 256, 512, 1024)},
        paper_table3=True),
    "rmsnorm": KernelInfo(
        "rmsnorm", RowBlockConfig,
        space={"block_rows": (8, 16, 32, 64, 128, 256, 512, 1024)},
        paper_table3=True),
    "swiglu": KernelInfo(
        "swiglu", EltwiseConfig,
        space={"block_rows": (8, 32, 64, 128, 256, 512),
               "block_cols": (128, 256, 512, 1024, 2048)},
        paper_table3=True),        # the paper's "SiLU" kernel (fused gate)
    "rope": KernelInfo(
        "rope", RopeConfig,
        space={"block_tokens": (8, 16, 32, 64, 128, 256, 512)},
        paper_table3=True),
    "attention": KernelInfo(
        "attention", AttentionConfig,
        space={"block_q": (64, 128, 256, 512),
               "block_k": (128, 256, 512, 1024)},
        paper_table3=False),       # beyond-paper kernel
    "flash_decode": KernelInfo(
        "flash_decode", DecodeAttentionConfig,
        space={"block_k": (64, 128, 256, 512, 1024),
               "k_splits": (1, 2, 4, 8, 16)},
        paper_table3=False),       # beyond-paper kernel (int8-KV decode)
    "flash_verify": KernelInfo(
        "flash_verify", VerifyAttentionConfig,
        space={"block_k": (64, 128, 256, 512, 1024),
               "k_splits": (1, 2, 4, 8, 16),
               "spec_len": (1, 2, 4, 8)},
        paper_table3=False),       # beyond-paper kernel (speculative verify)
    # paged variants: the split granularity IS the pool page (one program
    # per logical page), so page_size replaces k_splits as the tunable —
    # and it doubles as the serving engine's allocation granularity
    "paged_flash_decode": KernelInfo(
        "paged_flash_decode", PagedDecodeConfig,
        space={"block_k": (64, 128, 256, 512),
               "page_size": (16, 32, 64, 128)},
        paper_table3=False),       # beyond-paper kernel (paged KV decode)
    "paged_flash_verify": KernelInfo(
        "paged_flash_verify", PagedVerifyConfig,
        space={"block_k": (64, 128, 256, 512),
               "page_size": (16, 32, 64, 128),
               "spec_len": (1, 2, 4, 8)},
        paper_table3=False),       # beyond-paper kernel (paged verify)
}


def default_config(name: str):
    return KERNELS[name].config_cls()


def make_config(name: str, **fields):
    cfg = KERNELS[name].config_cls(**fields)
    cfg.validate()
    return cfg


def config_space(name: str) -> Dict[str, Tuple]:
    return dict(KERNELS[name].space)
