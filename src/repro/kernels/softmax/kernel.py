"""Pallas row softmax with optional logit softcap (Gemma-2).

Grid over row blocks; each step loads a (block_rows, C) tile into VMEM,
reduces along lanes, writes the normalized tile.  ``block_rows`` is the
HAQA-tunable (trades VMEM footprint against grid overhead).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common
from repro.kernels.common import RowBlockConfig


def _softmax_kernel(x_ref, o_ref, *, cap: float):
    x = x_ref[...].astype(jnp.float32)
    if cap and cap > 0:
        x = cap * jnp.tanh(x / cap)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


def softmax(x: jax.Array, cfg: RowBlockConfig, cap: float = 0.0,
            interpret: bool = False) -> jax.Array:
    r, c = x.shape
    br = min(cfg.block_rows, r)
    assert r % br == 0, (r, br)
    return pl.pallas_call(
        functools.partial(_softmax_kernel, cap=cap),
        grid=(r // br,),
        in_specs=[pl.BlockSpec((br, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), x.dtype),
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)
