"""Pure-jnp oracle for the (softcapped) row softmax."""
import jax
import jax.numpy as jnp


def softmax_ref(x: jax.Array, cap: float = 0.0) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cap and cap > 0:
        xf = cap * jnp.tanh(xf / cap)
    return jax.nn.softmax(xf, axis=-1).astype(x.dtype)
