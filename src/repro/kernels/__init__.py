from repro.kernels import attention, common, qmatmul, registry, rmsnorm, rope, softmax, swiglu
from repro.kernels.common import (
    AttentionConfig, DecodeAttentionConfig, EltwiseConfig, MatmulConfig,
    RopeConfig, RowBlockConfig,
)

__all__ = [
    "attention", "common", "qmatmul", "registry", "rmsnorm", "rope",
    "softmax", "swiglu",
    "AttentionConfig", "DecodeAttentionConfig", "EltwiseConfig",
    "MatmulConfig", "RopeConfig", "RowBlockConfig",
]
