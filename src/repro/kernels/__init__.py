from repro.kernels import attention, common, qmatmul, registry, rmsnorm, rope, softmax, swiglu
from repro.kernels.common import (
    AttentionConfig, EltwiseConfig, MatmulConfig, RopeConfig, RowBlockConfig,
)

__all__ = [
    "attention", "common", "qmatmul", "registry", "rmsnorm", "rope",
    "softmax", "swiglu",
    "AttentionConfig", "EltwiseConfig", "MatmulConfig", "RopeConfig",
    "RowBlockConfig",
]
