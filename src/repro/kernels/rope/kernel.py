"""Pallas RoPE kernel: rotate (tokens, heads*dim) tiles in VMEM.

Angles are computed in-kernel from the position ids (iota over the frequency
axis), so the only HBM traffic is x in / x out + a (tokens, 1) position
column — the memory-bound profile the paper's Table 3 RoPE rows show.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common
from repro.kernels.common import RopeConfig


def _rope_kernel(x_ref, pos_ref, o_ref, *, theta: float, heads: int, dim: int):
    x = x_ref[...].astype(jnp.float32)                  # (bt, H*D)
    bt = x.shape[0]
    pos = pos_ref[...].astype(jnp.float32)              # (bt, 1)
    half = dim // 2
    k = jax.lax.broadcasted_iota(jnp.float32, (1, half), 1)
    freqs = jnp.exp(-jnp.log(theta) * (2.0 * k / dim))  # (1, half)
    ang = pos * freqs                                   # (bt, half)
    cos = jnp.cos(ang)
    sin = jnp.sin(ang)
    xh = x.reshape(bt, heads, dim)
    x1 = xh[..., :half]
    x2 = xh[..., half:]
    c = cos[:, None, :]
    s = sin[:, None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    o_ref[...] = out.reshape(bt, heads * dim).astype(o_ref.dtype)


def rope(x2: jax.Array, pos2: jax.Array, heads: int, dim: int,
         cfg: RopeConfig, theta: float = 10_000.0,
         interpret: bool = False) -> jax.Array:
    """x2: (T, H*D) flattened tokens; pos2: (T, 1) int32."""
    t, hd = x2.shape
    bt = min(cfg.block_tokens, t)
    assert t % bt == 0
    return pl.pallas_call(
        functools.partial(_rope_kernel, theta=theta, heads=heads, dim=dim),
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, hd), lambda i: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, hd), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, hd), x2.dtype),
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x2, pos2)
