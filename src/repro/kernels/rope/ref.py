"""Pure-jnp oracle for the RoPE kernel (matches models.layers.apply_rope)."""
import jax
import jax.numpy as jnp


def rope_ref(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int."""
    d = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    angles = positions[..., None].astype(jnp.float32) * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
