"""jit'd wrapper for the RoPE kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import RopeConfig, round_up
from repro.kernels.rope import kernel as K

_DEFAULT_CFG = RopeConfig()


def set_default_config(cfg: RopeConfig) -> None:
    global _DEFAULT_CFG
    cfg.validate()
    _DEFAULT_CFG = cfg


def rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0,
         cfg: Optional[RopeConfig] = None, interpret: bool = False) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S)."""
    cfg = cfg or _DEFAULT_CFG
    b, s, h, d = x.shape
    t = b * s
    x2 = x.reshape(t, h * d)
    pos2 = positions.reshape(t, 1).astype(jnp.int32)
    bt = min(cfg.block_tokens, round_up(t, 8))
    tp = round_up(t, bt)
    if tp != t:
        x2 = jnp.pad(x2, ((0, tp - t), (0, 0)))
        pos2 = jnp.pad(pos2, ((0, tp - t), (0, 0)))
    out = K.rope(x2, pos2, h, d, RopeConfig(block_tokens=bt), theta=theta,
                 interpret=interpret)[:t]
    return out.reshape(b, s, h, d)
