"""Policy backends: the decision engines behind the HAQA loop.

All policies implement ``propose(space, history, context) -> Proposal`` so the
paper's comparisons (Table 1/2, Fig 4: HAQA vs Human / Local / Bayesian /
Random / NSGA2) are apples-to-apples — every method sees the same bounded
history and the same evaluation budget.

``SimulatedExpertPolicy`` is the offline stand-in for the paper's GPT-4 agent:
a deterministic rule engine distilled from the paper's published Appendix E
transcripts, consuming the same dynamic-prompt observations and emitting
ReAct Thought strings.  ``LLMBackend`` shows where a real API plugs in (it
renders the genuine Appendix-E prompts and parses/validates the JSON reply,
including the paper's §3.2 failure modes).
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.history import History, Trial
from repro.core.search_space import Categorical, SearchSpace, UniformFloat, UniformInt
from repro.core import prompts as prompt_lib


@dataclasses.dataclass
class Proposal:
    config: Dict[str, Any]
    thought: str = ""
    raw_text: str = ""                  # LLM raw reply (for format validation)


class Policy:
    name = "base"

    def propose(self, space: SearchSpace, history: History,
                context: Optional[Dict] = None) -> Proposal:
        raise NotImplementedError

    def reset(self) -> None:
        pass


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------

class DefaultPolicy(Policy):
    name = "default"

    def propose(self, space, history, context=None):
        return Proposal(space.defaults(), thought="Use the default configuration.")


class RandomSearchPolicy(Policy):
    name = "random"

    def __init__(self, seed: int = 0):
        self._seed = seed
        self.rng = np.random.default_rng(seed)

    def reset(self):
        self.rng = np.random.default_rng(self._seed)

    def propose(self, space, history, context=None):
        if len(history) == 0:
            return Proposal(space.defaults(), thought="Round 1: defaults.")
        return Proposal(space.sample(self.rng), thought="Uniform random sample.")


class LocalSearchPolicy(Policy):
    """Hill-climbing: perturb one dimension of the incumbent per round."""
    name = "local"

    def __init__(self, seed: int = 0, step: float = 0.25):
        self._seed = seed
        self.step = step
        self.rng = np.random.default_rng(seed)
        self._dim = 0

    def reset(self):
        self.rng = np.random.default_rng(self._seed)
        self._dim = 0

    def propose(self, space, history, context=None):
        if len(history) == 0:
            return Proposal(space.defaults(), thought="Round 1: defaults.")
        best = history.best()
        base = dict(best.config) if best else space.defaults()
        names = space.names
        pname = names[self._dim % len(names)]
        self._dim += 1
        spec = space.specs[pname]
        u = space.normalize(base)[names.index(pname)]
        direction = 1.0 if self.rng.random() < 0.5 else -1.0
        u_new = min(max(u + direction * self.step * self.rng.random(), 0.0), 1.0)
        base[pname] = spec.denormalize(u_new)
        return Proposal(space.clamp(base),
                        thought=f"Perturb '{pname}' around the incumbent.")


class BayesianGPPolicy(Policy):
    """GP (RBF kernel) + expected improvement over a random candidate pool."""
    name = "bayesian"

    def __init__(self, seed: int = 0, n_candidates: int = 512,
                 length_scale: float = 0.35, noise: float = 1e-4,
                 n_init: int = 3):
        self._seed = seed
        self.rng = np.random.default_rng(seed)
        self.nc = n_candidates
        self.ls = length_scale
        self.noise = noise
        self.n_init = n_init

    def reset(self):
        self.rng = np.random.default_rng(self._seed)

    def _kernel(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
        return np.exp(-0.5 * d2 / self.ls ** 2)

    def propose(self, space, history, context=None):
        ok = [t for t in history.trials if not t.failed]
        if len(history) == 0:
            return Proposal(space.defaults(), thought="Round 1: defaults.")
        if len(ok) < self.n_init:
            return Proposal(space.sample(self.rng),
                            thought="Initial design: random sample.")
        x = np.stack([space.normalize(t.config) for t in ok])
        y = np.array([t.objective for t in ok], dtype=np.float64)
        mu, sd = y.mean(), max(y.std(), 1e-8)
        yn = (y - mu) / sd
        k = self._kernel(x, x) + self.noise * np.eye(len(x))
        try:
            kinv_y = np.linalg.solve(k, yn)
            kinv = np.linalg.inv(k)
        except np.linalg.LinAlgError:
            return Proposal(space.sample(self.rng), thought="GP solve failed; random.")
        cand = np.stack([space.normalize(space.sample(self.rng))
                         for _ in range(self.nc)])
        kc = self._kernel(cand, x)
        pred = kc @ kinv_y
        var = np.clip(1.0 - np.einsum("ij,jk,ik->i", kc, kinv, kc), 1e-9, None)
        sig = np.sqrt(var)
        best = yn.max()
        z = (pred - best) / sig
        ei = sig * (z * _ncdf(z) + _npdf(z))
        pick = cand[int(np.argmax(ei))]
        return Proposal(space.clamp(space.denormalize(pick)),
                        thought="GP posterior: maximize expected improvement.")


def _ncdf(z):
    return 0.5 * (1 + np.vectorize(math.erf)(z / math.sqrt(2)))


def _npdf(z):
    return np.exp(-0.5 * z ** 2) / math.sqrt(2 * math.pi)


class NSGA2Policy(Policy):
    """Steady-state NSGA-II.  With a single objective it degenerates to a
    genetic algorithm (tournament select + SBX crossover + polynomial
    mutation); with (objective, -latency) pairs it uses nondominated sorting.
    """
    name = "nsga2"

    def __init__(self, seed: int = 0, pop: int = 8, mut_p: float = 0.3,
                 eta: float = 12.0):
        self._seed = seed
        self.rng = np.random.default_rng(seed)
        self.pop = pop
        self.mut_p = mut_p
        self.eta = eta

    def reset(self):
        self.rng = np.random.default_rng(self._seed)

    def propose(self, space, history, context=None):
        ok = [t for t in history.trials if not t.failed]
        if len(history) == 0:
            return Proposal(space.defaults(), thought="Round 1: defaults.")
        if len(ok) < max(3, self.pop // 2):
            return Proposal(space.sample(self.rng), thought="Seeding population.")
        front = self._select_front(ok)
        p1, p2 = (front[int(self.rng.integers(0, len(front)))] for _ in range(2))
        x1 = space.normalize(p1.config)
        x2 = space.normalize(p2.config)
        beta = self.rng.random(x1.shape)
        child = np.where(self.rng.random(x1.shape) < 0.5,
                         beta * x1 + (1 - beta) * x2,
                         beta * x2 + (1 - beta) * x1)
        mut = self.rng.random(child.shape) < self.mut_p
        child = np.where(mut, np.clip(
            child + self.rng.normal(0, 1.0 / self.eta, child.shape), 0, 1), child)
        return Proposal(space.clamp(space.denormalize(child)),
                        thought="NSGA-II: crossover + mutation on the front.")

    def _select_front(self, trials: List[Trial]) -> List[Trial]:
        objs = []
        multi = all("latency_us" in t.metrics for t in trials)
        for t in trials:
            if multi:
                objs.append((t.objective, -t.metrics["latency_us"]))
            else:
                objs.append((t.objective,))
        nondom = []
        for i, t in enumerate(trials):
            dominated = any(
                all(objs[j][k] >= objs[i][k] for k in range(len(objs[i])))
                and any(objs[j][k] > objs[i][k] for k in range(len(objs[i])))
                for j in range(len(trials)) if j != i)
            if not dominated:
                nondom.append(t)
        return nondom or trials


class HumanHeuristicPolicy(Policy):
    """Scripted 'experienced practitioner': the fixed playbook the paper's
    'Human' column represents (tune LR first, then regularization, roll back
    on regression — one knob at a time)."""
    name = "human"

    _MOVES = [
        {},                                       # defaults
        {"learning_rate": 0.5},                   # multiplicative on lr
        {"learning_rate": 2.0},
        {"weight_decay": 2.0},
        {"learning_rate": 0.3, "warmup_ratio": "+0.02"},
        {"momentum": "+0.05"},
        {"batch_size": 0.5, "per_device_train_batch_size": 0.5},
        {"lora_r": 2.0, "lora_alpha": 2.0},
        {"num_epochs": "+2", "max_steps": "+200"},
        {"learning_rate": 0.7, "weight_decay": 0.5},
    ]

    def __init__(self):
        self._i = 0

    def reset(self):
        self._i = 0

    def propose(self, space, history, context=None):
        best = history.best()
        base = dict(best.config) if best else space.defaults()
        move = self._MOVES[self._i % len(self._MOVES)]
        self._i += 1
        for k, v in move.items():
            if k not in base:
                continue
            if isinstance(v, str) and v.startswith("+"):
                base[k] = base[k] + type(base[k])(float(v[1:]))
            else:
                base[k] = type(base[k])(base[k] * v) if not isinstance(base[k], str) else base[k]
        return Proposal(space.clamp(base),
                        thought=f"Expert playbook move {self._i}: {move}")


# ---------------------------------------------------------------------------
# the HAQA brain (simulated expert)
# ---------------------------------------------------------------------------

_FT_EXPLORE_ORDER = [
    "learning_rate", "lora_r", "warmup_ratio", "weight_decay",
    "max_steps", "momentum", "num_epochs", "lora_dropout",
    "per_device_train_batch_size", "batch_size", "gradient_accumulation_steps",
    "max_grad_norm", "lora_alpha",
]


class SimulatedExpertPolicy(Policy):
    """Deterministic HAQA reasoning engine (offline GPT-4 stand-in).

    Finetune mode: exploit/rollback/explore rules distilled from the paper's
    Appendix E transcripts, with low-bit-aware priors (lower LR, longer
    warmup, tighter clipping for w2a2/int4 — the reason HAQA beats generic
    HPO under aggressive quantization).

    Deploy mode: reads the cost-model diagnosis (VMEM violation / memory- vs
    compute-bound / grid-overhead) and moves the corresponding tile knob —
    the hardware-aware reasoning of paper §3.4/§4.4.
    """
    name = "haqa"

    def __init__(self, seed: int = 0):
        self._seed = seed
        self.rng = np.random.default_rng(seed)
        self._explored: List[str] = []

    def reset(self):
        self.rng = np.random.default_rng(self._seed)
        self._explored = []

    # -- public ---------------------------------------------------------

    def propose(self, space, history, context=None):
        context = context or {}
        kind = context.get("kind", "finetune")
        if len(history) == 0:
            thought = ("First round: the task recommends starting from the "
                       "default configuration to establish a baseline.")
            cfg = space.defaults()
            cfg = self._lowbit_prior(space, cfg, context, first_round=True)
            return Proposal(cfg, thought=thought)
        if kind == "deploy":
            return self._propose_deploy(space, history, context)
        return self._propose_finetune(space, history, context)

    # -- finetune -------------------------------------------------------

    def _propose_finetune(self, space, history, context):
        best = history.best()
        last = history.last()
        base = dict(best.config) if best else space.defaults()
        objs = history.objectives()

        diverged = last.failed or (last.losses and
                                   (any(not math.isfinite(x) for x in last.losses)
                                    or (len(last.losses) > 2 and last.losses[-1] > 1.5 * last.losses[0])))
        improved = best is last and len(objs) >= 2
        plateau = (len(objs) >= 3 and max(objs[-2:]) <= max(objs[:-2]) + 1e-6)

        if diverged:
            cfg = dict(base)
            cfg = _scale(space, cfg, "learning_rate", 1 / 3)
            cfg = _scale(space, cfg, "max_grad_norm", 0.5)
            cfg = _bump(space, cfg, "warmup_ratio", +0.02)
            thought = ("The last run diverged (loss increased or went "
                       "non-finite). Under quantization the loss surface is "
                       "rougher: roll back to the best configuration, cut the "
                       "learning rate to a third, tighten gradient clipping, "
                       "and lengthen warmup for stability.")
            return Proposal(space.clamp(cfg), thought=thought)

        if improved and last.round >= 1:
            prev = history.trials[-2]
            changed = [k for k in base if
                       k in prev.config and _differs(base[k], prev.config[k])]
            cfg = dict(base)
            if changed:
                k = changed[0]
                ratio = _safe_ratio(base[k], prev.config[k])
                cfg = _scale(space, cfg, k, ratio ** 0.5)
                thought = (f"The change to '{k}' improved the objective — "
                           "continue in the same direction with a smaller "
                           "step to avoid overshooting the optimum.")
            else:
                cfg = _scale(space, cfg, "learning_rate", 0.8)
                cfg = _bump(space, cfg, "max_steps", +100)
                thought = ("Steady improvement: decay the learning rate "
                           "slightly and allow more optimization steps for "
                           "fine-grained convergence.")
            return Proposal(space.clamp(cfg), thought=thought)

        if plateau:
            pname = self._next_unexplored(space)
            cfg = dict(base)
            spec = space.specs[pname]
            u = spec.normalize(cfg.get(pname, spec.default))
            u_new = u + 0.3 if u < 0.5 else u - 0.3
            cfg[pname] = spec.denormalize(u_new)
            thought = (f"The objective has plateaued; the loss list suggests "
                       f"we are circling a local optimum. Explore a dimension "
                       f"not yet varied: move '{pname}' to a different region "
                       f"of its range while keeping the best settings for the "
                       f"other hyperparameters.")
            return Proposal(space.clamp(cfg), thought=thought)

        # mild regression: roll back with a gentler variant of the last move
        cfg = dict(base)
        cfg = _scale(space, cfg, "learning_rate", 1.2)
        cfg = _scale(space, cfg, "weight_decay", 0.7)
        thought = ("The last configuration slightly regressed. Return to the "
                   "best known settings and probe a mildly higher learning "
                   "rate with less regularization — the loss trace indicates "
                   "underfitting rather than instability.")
        return Proposal(space.clamp(cfg), thought=thought)

    def _lowbit_prior(self, space, cfg, context, first_round=False):
        bits = context.get("weight_bits", 16)
        if bits <= 4 and first_round:
            cfg = _scale(space, cfg, "learning_rate", 0.5)
            cfg = _bump(space, cfg, "warmup_ratio", +0.02)
            cfg = _scale(space, cfg, "max_grad_norm", 0.7)
        return cfg

    def _next_unexplored(self, space) -> str:
        for name in _FT_EXPLORE_ORDER:
            if name in space.specs and name not in self._explored:
                self._explored.append(name)
                return name
        self._explored = []
        return space.names[0]

    # -- deploy ---------------------------------------------------------

    def _propose_deploy(self, space, history, context):
        best = history.best()
        last = history.last()
        base = dict(best.config) if best else space.defaults()
        fb = context.get("feedback", {}) or last.metrics
        feasible = fb.get("feasible", True)
        bound = fb.get("bound", "")
        notes = fb.get("notes", "") or last.observation

        def bigger(cfg, key):
            return _move_categorical(space, cfg, key, +1)

        def smaller(cfg, key):
            return _move_categorical(space, cfg, key, -1)

        if not feasible or "VMEM" in notes:
            cfg = dict(last.config)
            key = _largest_tile_key(space, cfg)
            cfg = smaller(cfg, key)
            thought = (f"The working set exceeded VMEM — the kernel cannot be "
                       f"pipelined. Halve the largest tile ('{key}') to fit "
                       f"the ~16 MiB on-chip budget with double buffering.")
            return Proposal(space.clamp(cfg), thought=thought)

        if "grid overhead" in notes or "tiles too small" in notes:
            cfg = dict(base)
            for key in _tile_keys(space):
                cfg = bigger(cfg, key)
            thought = ("Per-grid-step overhead dominates: the tiles are too "
                       "small to amortize the pipeline bubbles. Increase all "
                       "block sizes one notch.")
            return Proposal(space.clamp(cfg), thought=thought)

        if bound == "memory":
            cfg = dict(base)
            for key in ("bk", "bm", "block_rows", "block_q"):
                if key in space.specs:
                    cfg = bigger(cfg, key)
                    thought = (f"HBM traffic dominates ({notes or 'memory bound'}): "
                               f"increase '{key}' so each operand tile is "
                               f"reused across more of the contraction, "
                               f"cutting re-reads.")
                    return Proposal(space.clamp(cfg), thought=thought)

        if bound == "compute":
            cfg = dict(base)
            if "dimension_semantics" in space.specs:
                cfg["dimension_semantics"] = space.specs["dimension_semantics"].choices[0]
            key = "bn" if "bn" in space.specs else _tile_keys(space)[0]
            cfg = bigger(cfg, key)
            thought = ("The kernel is compute-bound: ensure the row/col grid "
                       "dimensions are marked parallel so Mosaic overlaps DMA "
                       "with the MXU, and widen the output tile to raise MXU "
                       "occupancy.")
            return Proposal(space.clamp(cfg), thought=thought)

        # explore one knob around the incumbent
        keys = _tile_keys(space)
        key = keys[len(history) % len(keys)]
        cfg = _move_categorical(space, dict(base), key,
                                +1 if (len(history) // len(keys)) % 2 == 0 else -1)
        thought = (f"No dominant bottleneck reported; probe '{key}' around the "
                   "incumbent to map the latency surface.")
        return Proposal(space.clamp(cfg), thought=thought)


def _tile_keys(space) -> List[str]:
    return [n for n in space.names
            if n in ("bm", "bn", "bk", "block_rows", "block_cols",
                     "block_tokens", "block_q", "block_k")]


def _largest_tile_key(space, cfg) -> str:
    keys = _tile_keys(space)
    return max(keys, key=lambda k: cfg.get(k, 0)) if keys else space.names[0]


def _move_categorical(space, cfg, key, delta):
    spec = space.specs.get(key)
    if spec is None or not isinstance(spec, Categorical):
        return cfg
    try:
        i = spec.choices.index(cfg.get(key, spec.default))
    except ValueError:
        i = 0
    cfg[key] = spec.choices[min(max(i + delta, 0), len(spec.choices) - 1)]
    return cfg


def _scale(space, cfg, key, factor):
    if key in space.specs and key in cfg:
        spec = space.specs[key]
        v = cfg[key] * factor
        cfg[key] = spec.clamp(int(round(v)) if isinstance(spec, UniformInt) else v)
    return cfg


def _bump(space, cfg, key, delta):
    if key in space.specs and key in cfg:
        spec = space.specs[key]
        cfg[key] = spec.clamp(cfg[key] + delta)
    return cfg


def _differs(a, b) -> bool:
    try:
        return abs(float(a) - float(b)) > 1e-12
    except (TypeError, ValueError):
        return a != b


def _safe_ratio(a, b) -> float:
    try:
        fa, fb = float(a), float(b)
        if fb == 0:
            return 1.0
        r = fa / fb
        return min(max(r, 0.25), 4.0)
    except (TypeError, ValueError):
        return 1.0


# ---------------------------------------------------------------------------
# real-LLM backend (API plug point)
# ---------------------------------------------------------------------------

class LLMBackend(Policy):
    """Formats the genuine Appendix-E prompts and parses the model's JSON.

    ``complete_fn(messages) -> str`` is the injection point: a real deployment
    wires an API client here (the paper used GPT-4-0613); tests inject fakes —
    including misbehaving ones, to exercise the paper's §3.2 failure handling.
    """
    name = "llm"

    def __init__(self, complete_fn: Optional[Callable[[List[Dict]], str]] = None,
                 static_prompt_text: str = ""):
        self.complete_fn = complete_fn
        self.static_prompt_text = static_prompt_text

    def propose(self, space, history, context=None):
        if self.complete_fn is None:
            raise RuntimeError(
                "LLMBackend has no completion function. This container is "
                "offline; inject complete_fn or use SimulatedExpertPolicy.")
        context = context or {}
        rounds_left = context.get("rounds_left", 0)
        messages = prompt_lib.full_prompt(
            self.static_prompt_text, history, rounds_left,
            losses=context.get("losses"))
        text = self.complete_fn(messages)
        cfg = extract_json_config(text)
        if cfg is None:
            raise FormatError(f"no JSON object found in reply: {text[:200]!r}")
        return Proposal(cfg, thought=text.split("{")[0].strip(), raw_text=text)


class FormatError(ValueError):
    """Paper §3.2 issue 1: the reply did not follow the required format."""


def extract_json_config(text: str) -> Optional[Dict[str, Any]]:
    """Pull the last top-level JSON object out of an LLM reply."""
    matches = re.findall(r"\{[^{}]*\}", text, re.DOTALL)
    for m in reversed(matches):
        try:
            obj = json.loads(m)
            if isinstance(obj, dict):
                return obj
        except json.JSONDecodeError:
            continue
    return None


ALL_BASELINES = {
    "default": DefaultPolicy,
    "random": RandomSearchPolicy,
    "local": LocalSearchPolicy,
    "bayesian": BayesianGPPolicy,
    "nsga2": NSGA2Policy,
    "human": HumanHeuristicPolicy,
    "haqa": SimulatedExpertPolicy,
}


def make_policy(name: str, seed: int = 0) -> Policy:
    cls = ALL_BASELINES[name]
    try:
        return cls(seed=seed)
    except TypeError:
        return cls()
