from repro.core.agent import AgentConfig, EvalResult, HAQAgent, JointAgent
from repro.core.history import History, Trial
from repro.core.policies import (
    ALL_BASELINES, BayesianGPPolicy, DefaultPolicy, FormatError,
    HumanHeuristicPolicy, LLMBackend, LocalSearchPolicy, NSGA2Policy,
    Policy, Proposal, RandomSearchPolicy, SimulatedExpertPolicy,
    extract_json_config, make_policy,
)
from repro.core.search_space import (
    Categorical, SearchSpace, UniformFloat, UniformInt,
    bitwidth_space, deploy_space, llama_finetune_space, resnet_finetune_space,
    serve_space,
)
from repro.core.hardware import REGISTRY as HARDWARE_REGISTRY, HardwareSpec, Support, get_hardware
from repro.core import adaptive, costmodel, memory_planner, prompts
from repro.core.evaluator import (
    DecodeEvaluator, FaultInjection, FinetuneEvaluator, KernelEvaluator,
)

__all__ = [
    "AgentConfig", "EvalResult", "HAQAgent", "JointAgent", "History", "Trial",
    "ALL_BASELINES", "BayesianGPPolicy", "DefaultPolicy", "FormatError",
    "HumanHeuristicPolicy", "LLMBackend", "LocalSearchPolicy", "NSGA2Policy",
    "Policy", "Proposal", "RandomSearchPolicy", "SimulatedExpertPolicy",
    "extract_json_config", "make_policy",
    "Categorical", "SearchSpace", "UniformFloat", "UniformInt",
    "bitwidth_space", "deploy_space", "llama_finetune_space",
    "resnet_finetune_space", "serve_space",
    "HARDWARE_REGISTRY", "HardwareSpec", "Support", "get_hardware",
    "adaptive", "costmodel", "memory_planner", "prompts",
    "DecodeEvaluator", "FaultInjection", "FinetuneEvaluator", "KernelEvaluator",
]
