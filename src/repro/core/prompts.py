"""HAQA prompt assembly — faithful to the paper's Fig 2 / Appendix E design.

Static prompt  = hardware block + objective block(s) + search space + ReAct
                 preamble (unchanged across rounds).
Dynamic prompt = rounds-remaining note + current config + evaluation feedback
                 + bounded history (updated every round).

These strings are what an ``LLMBackend`` would send to a real model; the
``SimulatedExpertPolicy`` consumes the same structured content.  Rendering
them even in simulated mode keeps the workflow (and its logs) faithful.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.core.hardware import HardwareSpec
from repro.core.history import History
from repro.core.search_space import SearchSpace

REACT_PREAMBLE = (
    "Before making a decision, always generate a reasoning step (Thought) to "
    "analyze the current context, considering previous results and constraints. "
    "Then, take an appropriate action (Action) based on your reasoning. After "
    "the action, observe (Observation) the outcomes we feedback to you and "
    "adjust your approach accordingly. Identify missing information, potential "
    "errors, and formulate a strategy before taking any action. Each trial's "
    "configuration and results should be taken into account for a "
    "**comprehensive** analysis of the optimization process. Please review the "
    "history and consider your next steps before proceeding.\n"
    "**Make sure that all hyperparameters remain within the defined range**. "
    "For the **first round**, it is recommended to use the **default "
    "parameters**. Please provide the configuration in **JSON format**.")

SYSTEM_PROMPT = (
    "You are an expert assistant specialized in optimizing hyperparameters for "
    "both fine-tuning and deployment of a certain neural network. Your goal is "
    "to help improve the accuracy and inference speed of the network by "
    "providing optimized hyperparameter configurations and code.")


def static_prompt(task: str, model_desc: str, quant_desc: str,
                  hw: Optional[HardwareSpec], space: SearchSpace,
                  memory_limit_gb: Optional[float] = None,
                  core_code_refs: Optional[List[str]] = None,
                  extra: str = "") -> str:
    parts = [f"You are helping optimize the hyperparameters of {task} for "
             f"{model_desc}. Using [{quant_desc}] quantization."]
    if hw is not None:
        parts.append(
            "I plan to deploy the model on the following hardware. Here's more "
            f"details about the hardware:\n{hw.prompt_text()}")
        if memory_limit_gb is not None:
            parts.append(
                f"The memory limit is {memory_limit_gb} GB. Please choose an "
                "appropriate quantization bit width that satisfies the memory "
                "limitations and achieves better performance on such hardware.")
    parts.append("Here is the hyperparameter search space:\n" + space.prompt_text())
    if core_code_refs:
        parts.append("Core code for the task: " + ", ".join(core_code_refs))
    if extra:
        parts.append(extra)
    parts.append(REACT_PREAMBLE)
    example = json.dumps({n: "x" for n in space.names})
    parts.append(f"For example: {example}")
    return "\n\n".join(parts)


def dynamic_prompt(history: History, rounds_left: int,
                   losses: Optional[List[float]] = None) -> str:
    parts = [f"Note that there are {rounds_left} rounds left, please try to "
             "make effective attempts. Finishing tasks with interleaving "
             "Thought, Action, Observation steps."]
    last = history.last()
    if last is not None:
        parts.append("The current configuration is: "
                     + json.dumps(last.config, default=str))
        parts.append("The result based on this configuration: "
                     + json.dumps(last.metrics))
        if last.observation:
            parts.append("Observation: " + last.observation)
    if losses:
        shown = [round(x, 4) for x in losses[-16:]]
        parts.append(f"List of recent training losses (avg per epoch): {shown}")
    window = history.window()
    if len(window) > 1:
        hist_lines = [
            {"round": t.round, "config": t.config,
             "objective": round(t.objective, 4)}
            for t in window[:-1]
        ]
        parts.append("History: " + json.dumps(hist_lines, default=str))
    parts.append("Please check the history and think about your next plan "
                 "before action. Please optimize and provide a set of "
                 "optimized configurations.")
    return "\n".join(parts)


def full_prompt(static: str, history: History, rounds_left: int,
                losses=None) -> List[Dict[str, str]]:
    """The messages array an OpenAI-style API would receive (Appendix E)."""
    return [
        {"role": "system", "content": SYSTEM_PROMPT},
        {"role": "user", "content": static},
        {"role": "user", "content": dynamic_prompt(history, rounds_left, losses)},
    ]
