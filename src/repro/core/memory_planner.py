"""Memory-constrained quantization feasibility (paper Table 5).

Given a model, a hardware platform and a memory limit, compute the deployment
footprint of each quantization type and reject configurations that do not
fit — the check HAQA runs before proposing a bit-width.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core import costmodel
from repro.core.hardware import HardwareSpec

SCHEMES = ("fp16", "int8", "int4")


@dataclasses.dataclass
class PlanEntry:
    scheme: str
    footprint_gb: float
    fits: bool
    throughput_tps: float
    rationale: str


def plan(cfg: ModelConfig, memory_limit_gb: float, hw: HardwareSpec,
         batch: int = 1, context: int = 2048) -> List[PlanEntry]:
    entries = []
    for scheme in SCHEMES:
        gb = costmodel.model_memory_gb(cfg, scheme, batch, context)
        fits = gb <= memory_limit_gb
        tput = costmodel.decode_throughput(cfg, batch, context, hw, scheme) if fits else 0.0
        if fits:
            rationale = (f"{scheme} needs {gb:.1f} GB <= {memory_limit_gb} GB; "
                         f"predicted {tput:.2f} tok/s on {hw.name}")
        else:
            rationale = (f"rejected: {scheme} needs {gb:.1f} GB "
                         f"> {memory_limit_gb} GB limit")
        entries.append(PlanEntry(scheme, gb, fits, tput, rationale))
    return entries


def feasibility_table(cfg: ModelConfig, limits_gb, hw: HardwareSpec
                      ) -> Dict[float, Dict[str, bool]]:
    """The paper's Table 5 matrix: limit -> {scheme: fits}."""
    return {lim: {e.scheme: e.fits for e in plan(cfg, lim, hw)}
            for lim in limits_gb}


def select(cfg: ModelConfig, memory_limit_gb: float, hw: HardwareSpec,
           batch: int = 1, context: int = 2048) -> Optional[PlanEntry]:
    """Best feasible scheme by predicted throughput (HAQA's choice)."""
    feasible = [e for e in plan(cfg, memory_limit_gb, hw, batch, context) if e.fits]
    return max(feasible, key=lambda e: e.throughput_tps) if feasible else None
