"""Hardware descriptor registry — the knowledge HAQA's adaptive quantization
reasons over (§3.4/§4.4).

Each spec records per-dtype peak throughput and *support level*: NATIVE means
the matrix unit consumes the dtype directly; EMULATED means values must be
converted/unpacked first (the paper's OnePlus INT4 case — and, natively on
TPU, int4 which has no MXU path).  The cost model charges emulation.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Optional


class Support(str, enum.Enum):
    NATIVE = "native"
    EMULATED = "emulated"
    UNSUPPORTED = "unsupported"


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    vendor: str
    kind: str                       # tpu | gpu | mobile_soc | cpu
    memory_gb: float                # device memory (HBM / unified)
    mem_bw: float                   # bytes/s
    fast_mem_bytes: int             # VMEM / shared memory per core
    link_bw: float                  # ICI / NVLink bytes/s per link
    dcn_bw: float                   # inter-pod bytes/s
    peak_flops: Dict[str, float]    # dtype -> flop/s (matrix unit)
    support: Dict[str, Support]     # dtype -> support level
    vector_ops: float               # scalar/vector unit ops/s (emulation cost)
    grid_step_overhead_s: float     # per grid-step launch/pipeline bubble
    notes: str = ""
    # achievable fraction of peak for the batch-1 decode matvec path, per
    # deployment scheme.  Calibrated so the model reproduces the paper's
    # measured orderings (Table 4 mobile: int8 marginally > fp16 > int4;
    # Fig 5 A6000: int4 > int8 > fp16).  TPUs sustain high matvec fractions
    # when weights stream through VMEM.
    matvec_eff: Optional[Dict[str, float]] = None

    def decode_eff(self, scheme: str) -> float:
        if not self.matvec_eff:
            return 0.8
        return self.matvec_eff.get(scheme, 0.5)

    def peak(self, dtype: str) -> float:
        if dtype not in self.peak_flops:
            # emulated dtypes run at the precision they convert to
            conv = {"int4": "fp16", "int8": "fp16", "fp16": "fp16"}
            return self.peak_flops.get(conv.get(dtype, "fp16"), 1e12)
        return self.peak_flops[dtype]

    def supports(self, dtype: str) -> Support:
        return self.support.get(dtype, Support.UNSUPPORTED)

    def prompt_text(self) -> str:
        """Render as the paper's static-prompt hardware block."""
        sup = {d: s.value for d, s in self.support.items()}
        peaks = {d: f"{v/1e12:.0f} TFLOPS" for d, v in self.peak_flops.items()}
        return (f'{{"Device": "{self.name}", "Vendor": "{self.vendor}", '
                f'"Kind": "{self.kind}", "Memory": "{self.memory_gb} GB", '
                f'"Memory Bandwidth": "{self.mem_bw/1e9:.0f} GB/s", '
                f'"Peak throughput": {peaks}, "Dtype support": {sup}, '
                f'"Notes": "{self.notes}"}}')


# --- registry ---------------------------------------------------------------

TPU_V5E = HardwareSpec(
    name="tpu-v5e", vendor="Google", kind="tpu",
    memory_gb=16.0, mem_bw=819e9, fast_mem_bytes=16 * 2**20,
    link_bw=50e9, dcn_bw=25e9,
    peak_flops={"bf16": 197e12, "fp16": 197e12, "fp32": 49e12, "int8": 394e12},
    support={"fp32": Support.NATIVE, "bf16": Support.NATIVE,
             "fp16": Support.NATIVE, "int8": Support.NATIVE,
             "int4": Support.EMULATED},
    vector_ops=6e12, grid_step_overhead_s=1.0e-6,
    matvec_eff={"fp16": 0.8, "bf16": 0.8, "int8": 0.8, "w8a8": 0.8, "int4": 0.7},
    notes="MXU 128x128 systolic; int8 native at 2x bf16; no int4 MXU path "
          "(weights must be unpacked to int8/bf16 on the VPU first)")

TPU_V4 = HardwareSpec(
    name="tpu-v4", vendor="Google", kind="tpu",
    memory_gb=32.0, mem_bw=1228e9, fast_mem_bytes=16 * 2**20,
    link_bw=50e9, dcn_bw=25e9,
    peak_flops={"bf16": 275e12, "fp16": 275e12, "fp32": 69e12},
    support={"fp32": Support.NATIVE, "bf16": Support.NATIVE,
             "fp16": Support.NATIVE, "int8": Support.EMULATED,
             "int4": Support.EMULATED},
    vector_ops=8e12, grid_step_overhead_s=1.0e-6,
    matvec_eff={"fp16": 0.8, "bf16": 0.8, "int8": 0.7, "w8a8": 0.55, "int4": 0.6},
    notes="no int8 MXU: int8/int4 weights convert to bf16 before the MXU "
          "(weight-only quantization still saves HBM bandwidth)")

NVIDIA_A6000 = HardwareSpec(
    name="nvidia-a6000", vendor="NVIDIA", kind="gpu",
    memory_gb=48.0, mem_bw=768e9, fast_mem_bytes=100 * 1024,
    link_bw=56e9, dcn_bw=12.5e9,
    peak_flops={"fp16": 309e12, "bf16": 309e12, "fp32": 38.7e12,
                "int8": 618e12, "int4": 1236e12},
    support={"fp32": Support.NATIVE, "fp16": Support.NATIVE,
             "bf16": Support.NATIVE, "int8": Support.NATIVE,
             "int4": Support.NATIVE},
    vector_ops=19e12, grid_step_overhead_s=3.0e-6,
    matvec_eff={"fp16": 0.45, "bf16": 0.45, "int8": 0.5, "w8a8": 0.5, "int4": 0.5},
    notes="Ampere, 10752 CUDA cores, 336 3rd-gen Tensor Cores; IMMA int4/int8 "
          "with fp32 accumulate")

SNAPDRAGON_8GEN2 = HardwareSpec(
    name="snapdragon-8gen2", vendor="Qualcomm", kind="mobile_soc",
    memory_gb=16.0, mem_bw=67e9, fast_mem_bytes=64 * 1024,
    link_bw=0.0, dcn_bw=0.0,
    peak_flops={"fp16": 8e12, "int8": 10e12},
    support={"fp32": Support.NATIVE, "fp16": Support.NATIVE,
             "int8": Support.NATIVE, "int4": Support.EMULATED},
    vector_ops=1e12, grid_step_overhead_s=10.0e-6,
    # llama.cpp-on-Adreno achievable rates (calibrated to the paper's
    # Table 4: ~5 tok/s for a 3B fp16 model; int8 marginally faster; int4
    # falls off the optimized path entirely)
    matvec_eff={"fp16": 0.0040, "int8": 0.0043, "w8a8": 0.0043, "int4": 0.0028},
    notes="Adreno 740 (768 ALUs) + Hexagon accelerators; int4 not natively "
          "supported — emulated via int8/fp16 with bitwise unpack (paper §4.4)")

CPU_HOST = HardwareSpec(
    name="cpu-host", vendor="generic", kind="cpu",
    memory_gb=32.0, mem_bw=40e9, fast_mem_bytes=1 * 2**20,
    link_bw=0.0, dcn_bw=0.0,
    peak_flops={"fp32": 0.2e12, "bf16": 0.2e12, "fp16": 0.2e12,
                "int8": 0.4e12},
    support={"fp32": Support.NATIVE, "bf16": Support.EMULATED,
             "fp16": Support.EMULATED, "int8": Support.NATIVE,
             "int4": Support.EMULATED},
    vector_ops=0.1e12, grid_step_overhead_s=0.2e-6,
    notes="validation host (interpret mode)")

REGISTRY: Dict[str, HardwareSpec] = {
    h.name: h for h in [TPU_V5E, TPU_V4, NVIDIA_A6000, SNAPDRAGON_8GEN2, CPU_HOST]
}


def get_hardware(name: str) -> HardwareSpec:
    if name not in REGISTRY:
        raise KeyError(f"unknown hardware '{name}'; known: {sorted(REGISTRY)}")
    return REGISTRY[name]
