"""HAQAgent — the paper's Fig 3 optimization loop.

Per round: render prompt → policy proposes (Thought/Action) → validate the
configuration against the search space (handling the paper's §3.2 failure
modes: bad format, constraint violations, irrelevant keys — with bounded
retries, then clamping) → run the trial (Observation) → update the bounded
history → repeat until the round budget or the target is reached.

Joint mode tunes a fine-tuning space and a deployment space in the same
conversation (Fig 1b: "jointly tunes all settings").
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

from repro.core.history import History, Trial
from repro.core.policies import FormatError, Policy, Proposal
from repro.core.search_space import SearchSpace
from repro.core import prompts as prompt_lib


@dataclasses.dataclass
class AgentConfig:
    max_rounds: int = 10                 # the paper's budget
    max_retries: int = 2                 # format/constraint retry budget
    history_len: int = 10                # §3.3 bounded history
    target_objective: Optional[float] = None
    verbose: bool = False


@dataclasses.dataclass
class EvalResult:
    metrics: Dict[str, float]
    objective: float
    observation: str = ""
    losses: List[float] = dataclasses.field(default_factory=list)
    failed: bool = False
    feedback: Optional[Dict] = None      # structured diagnosis (deploy mode)


Evaluator = Callable[[Dict[str, Any]], EvalResult]


class HAQAgent:
    def __init__(self, space: SearchSpace, evaluator: Evaluator,
                 policy: Policy, config: Optional[AgentConfig] = None,
                 context: Optional[Dict] = None,
                 static_prompt_text: str = ""):
        self.space = space
        self.evaluator = evaluator
        self.policy = policy
        self.config = config or AgentConfig()
        self.context = dict(context or {})
        self.history = History(max_len=self.config.history_len)
        self.static_prompt_text = static_prompt_text
        self.react_trace: List[Dict[str, str]] = []
        self.validation_events: List[str] = []

    # -- single round -----------------------------------------------------

    def step(self, round_idx: int) -> Trial:
        rounds_left = self.config.max_rounds - round_idx
        ctx = dict(self.context)
        ctx["rounds_left"] = rounds_left
        last = self.history.last()
        if last is not None:
            ctx["losses"] = last.losses
            ctx["feedback"] = last.metrics if last.metrics.get("feasible") is not None else ctx.get("feedback")

        proposal = self._propose_validated(ctx)
        t0 = time.time()
        try:
            result = self.evaluator(proposal.config)
        except Exception as e:  # evaluator crash = failed trial, not agent crash
            result = EvalResult(metrics={}, objective=float("-inf"),
                                observation=f"trial crashed: {e}", failed=True)
        wall = time.time() - t0

        trial = Trial(round=round_idx, config=proposal.config,
                      metrics=result.metrics, objective=result.objective,
                      thought=proposal.thought, observation=result.observation,
                      losses=result.losses, wall_s=wall, failed=result.failed)
        self.history.append(trial)
        if result.feedback is not None:
            self.context["feedback"] = result.feedback
        self.react_trace.append({
            "round": str(round_idx),
            "thought": proposal.thought,
            "action": str(proposal.config),
            "observation": result.observation or str(result.metrics),
        })
        if self.config.verbose:
            print(f"[haqa:{self.policy.name}] round {round_idx}: "
                  f"obj={result.objective:.4f} {proposal.config}")
        return trial

    def _propose_validated(self, ctx) -> Proposal:
        """Paper §3.2: retry on format errors / constraint violations /
        irrelevant keys; clamp as the final fallback."""
        errors: List[str] = []
        for attempt in range(self.config.max_retries + 1):
            try:
                proposal = self.policy.propose(self.space, self.history, ctx)
            except FormatError as e:
                errors.append(f"format error: {e}")
                self.validation_events.append(errors[-1])
                ctx = {**ctx, "validation_errors": list(errors)}
                continue
            violations = self.space.validate(proposal.config)
            if not violations:
                return proposal
            errors.extend(violations)
            self.validation_events.append(
                f"attempt {attempt}: {'; '.join(violations)}")
            ctx = {**ctx, "validation_errors": list(errors)}
        # final fallback: clamp into range and strip irrelevant keys
        clamped = self.space.clamp(proposal.config if 'proposal' in locals()
                                   else {})
        self.validation_events.append("clamped out-of-range proposal")
        return Proposal(clamped, thought=(getattr(proposal, "thought", "")
                                          + " [clamped to constraints]"))

    # -- full run -----------------------------------------------------------

    def run(self) -> History:
        self.policy.reset()
        for r in range(self.config.max_rounds):
            trial = self.step(r)
            tgt = self.config.target_objective
            if tgt is not None and trial.objective >= tgt:
                break
        return self.history

    def best_config(self) -> Dict[str, Any]:
        best = self.history.best()
        return best.config if best else self.space.defaults()

    def suggestions(self) -> str:
        """§3.3: optimization suggestions surfaced to the user."""
        best = self.history.best()
        if best is None:
            return "No successful trial yet; consider widening the search space."
        lines = [f"Best objective {best.objective:.4f} at round {best.round} "
                 f"with {best.config}."]
        objs = self.history.objectives()
        if len(objs) >= 3 and max(objs[-2:]) <= max(objs[:-2]):
            lines.append("Recent rounds plateaued — consider narrowing ranges "
                         "around the best configuration or adding rounds.")
        return " ".join(lines)


# ---------------------------------------------------------------------------
# joint fine-tune + deployment agent (Fig 1b)
# ---------------------------------------------------------------------------

class JointAgent:
    """One conversation optimizing both spaces: each round proposes a
    fine-tune config and a deployment config, mirrored on the paper's
    Llama2-7b Appendix-E transcript."""

    def __init__(self, ft_space: SearchSpace, ft_eval: Evaluator,
                 deploy_space: SearchSpace, deploy_eval: Evaluator,
                 policy_factory: Callable[[], Policy],
                 config: Optional[AgentConfig] = None,
                 ft_context: Optional[Dict] = None,
                 deploy_context: Optional[Dict] = None):
        cfg = config or AgentConfig()
        self.ft = HAQAgent(ft_space, ft_eval, policy_factory(), cfg,
                           {**(ft_context or {}), "kind": "finetune"})
        self.deploy = HAQAgent(deploy_space, deploy_eval, policy_factory(), cfg,
                               {**(deploy_context or {}), "kind": "deploy"})
        self.config = cfg

    def run(self):
        self.ft.policy.reset()
        self.deploy.policy.reset()
        for r in range(self.config.max_rounds):
            self.ft.step(r)
            self.deploy.step(r)
        return self.ft.history, self.deploy.history
