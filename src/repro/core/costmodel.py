"""Analytical TPU latency model.

This is the deployment-side feedback signal for HAQA (the container has no
TPU attached, so the paper's measured kernel latencies are replaced by a
first-principles model over the hardware descriptors — documented in
DESIGN.md §2).  The model captures exactly the phenomena the paper's agent
exploits:

* tile sizes trade HBM re-reads (big tiles reuse operands) against VMEM
  pressure (infeasible when the working set exceeds VMEM),
* hardware alignment (MXU/VPU tile granularity) — misaligned tiles waste
  systolic cycles,
* grid-step overhead — tiny tiles drown in pipeline bubbles,
* dtype support — NATIVE int8 doubles MXU throughput on v5e, while EMULATED
  int4 pays a VPU unpack per weight element (the §4.4 counter-intuitive case),
* compute/memory overlap — roofline-style max() when double-buffering fits.

All latencies are seconds.  ``notes`` carries a human-readable diagnosis that
feeds the agent's dynamic prompt (its "Observation").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.configs.base import ModelConfig
from repro.core.hardware import HardwareSpec, Support

INFEASIBLE = float("inf")


@dataclasses.dataclass
class Latency:
    total: float
    compute: float = 0.0
    memory: float = 0.0
    overhead: float = 0.0
    emulation: float = 0.0
    feasible: bool = True
    bound: str = ""
    notes: str = ""

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def _scheme_bytes(scheme: str):
    """(x_bytes, w_bytes, out_bytes, compute_dtype, weight_only)"""
    return {
        "fp32": (4.0, 4.0, 4.0, "fp32", False),
        "fp16": (2.0, 2.0, 2.0, "bf16", False),
        "bf16": (2.0, 2.0, 2.0, "bf16", False),
        "int8": (2.0, 1.0, 2.0, "bf16", True),    # weight-only int8
        "w8a8": (1.0, 1.0, 2.0, "int8", False),   # full int8
        "int4": (2.0, 0.5, 2.0, "bf16", True),    # weight-only packed int4
    }[scheme]


def _ceil_div(a, b):
    return -(-a // b)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

def matmul_latency(m: int, k: int, n: int, hw: HardwareSpec,
                   scheme: str = "bf16", bm: int = 128, bn: int = 128,
                   bk: int = 512, dimension_semantics=("parallel", "parallel", "arbitrary"),
                   **_ignored) -> Latency:
    xb, wb, ob, cdtype, weight_only = _scheme_bytes(scheme)
    bm = max(1, min(bm, _round8(m)))
    bn = min(bn, _round128(n)) if n >= 128 else n
    bk = min(bk, _round128(k)) if k >= 128 else k

    gm, gn, gk = _ceil_div(m, bm), _ceil_div(n, bn), _ceil_div(k, bk)
    mp, np_, kp = gm * bm, gn * bn, gk * bk

    # VMEM working set (double-buffered in/out + accumulator)
    vmem = 2 * (bm * bk * xb + bk * bn * wb) + bm * bn * (4 + ob)
    if weight_only:
        vmem += bk * bn * 4          # dequantized tile staging
    if vmem > hw.fast_mem_bytes:
        return Latency(total=INFEASIBLE, feasible=False, bound="vmem",
                       notes=f"VMEM working set {vmem/2**20:.1f} MiB exceeds "
                             f"{hw.fast_mem_bytes/2**20:.0f} MiB — shrink tiles")

    # alignment waste: MXU wants (128,128); VPU lanes 128 / sublane 8
    align = 1.0
    if bm % 8:
        align *= 1.5
    if bn % 128 or bk % 128:
        align *= 2.0

    flops = 2.0 * mp * kp * np_
    sup = hw.supports({"int8": "int8", "w8a8": "int8", "int4": "int4"}.get(scheme, "bf16"))
    peak = hw.peak(cdtype if not (scheme == "w8a8" and sup == Support.NATIVE) else "int8")
    t_compute = flops * align / peak

    # emulation: unpack/convert quantized weights per tile visit
    t_emul = 0.0
    emul_note = ""
    if scheme == "int4":
        ops_per_elem = 4.0 if sup != Support.NATIVE else 0.0   # shifts/ands/stack
        t_emul = ops_per_elem * kp * np_ * gm / hw.vector_ops
        if sup != Support.NATIVE:
            emul_note = "int4 has no native matrix path: per-tile nibble unpack on the vector unit"
    elif weight_only:                                           # int8 weight-only
        conv = 1.0 if hw.supports("int8") != Support.NATIVE else 0.5
        t_emul = conv * kp * np_ * gm / hw.vector_ops
    elif scheme == "w8a8" and sup != Support.NATIVE:
        t_emul = 2.0 * (mp * kp * gn + kp * np_ * gm) / hw.vector_ops
        emul_note = "int8 matrix path not native: converts to fp16 before the matrix unit"

    # HBM traffic with blocked reuse (outputs accumulate in VMEM)
    traffic = mp * kp * xb * gn + kp * np_ * wb * gm + mp * np_ * ob
    t_mem = traffic / hw.mem_bw

    steps = gm * gn * gk
    pipelined = dimension_semantics and tuple(dimension_semantics[:2]) == ("parallel", "parallel")
    t_over = steps * hw.grid_step_overhead_s * (0.1 if pipelined else 1.0)

    # double-buffering overlaps compute with DMA when VMEM headroom exists
    overlap = vmem * 1.5 < hw.fast_mem_bytes
    busy = max(t_compute + t_emul, t_mem) if overlap else (t_compute + t_emul + t_mem)
    bound = "compute" if (t_compute + t_emul) >= t_mem else "memory"
    total = busy + t_over
    notes = []
    if emul_note:
        notes.append(emul_note)
    if t_over > 0.2 * total:
        notes.append("grid overhead dominates — tiles too small")
    if bound == "memory" and gm > 1:
        notes.append("weight tiles re-read per row block — larger bm/bk increases reuse")
    return Latency(total=total, compute=t_compute, memory=t_mem,
                   overhead=t_over, emulation=t_emul, bound=bound,
                   notes="; ".join(notes))


def _round8(x):
    return max(8, -(-x // 8) * 8)


def _round128(x):
    return max(128, -(-x // 128) * 128)


# ---------------------------------------------------------------------------
# row/eltwise kernels
# ---------------------------------------------------------------------------

def _rowwise_latency(rows: int, cols: int, hw: HardwareSpec, *,
                     ops_per_elem: float, n_buffers: float, block_rows: int,
                     itemsize: float = 2.0) -> Latency:
    br = max(1, min(block_rows, _round8(rows)))
    g = _ceil_div(rows, br)
    rp = g * br
    vmem = n_buffers * br * cols * 4
    if vmem > hw.fast_mem_bytes:
        return Latency(total=INFEASIBLE, feasible=False, bound="vmem",
                       notes=f"row block {br} x {cols} exceeds VMEM — shrink block_rows")
    t_comp = ops_per_elem * rp * cols / hw.vector_ops
    t_mem = n_buffers * rp * cols * itemsize / hw.mem_bw
    t_over = g * hw.grid_step_overhead_s * 0.1
    total = max(t_comp, t_mem) + t_over
    bound = "compute" if t_comp >= t_mem else "memory"
    notes = "grid overhead dominates — increase block_rows" if t_over > 0.2 * total else ""
    return Latency(total=total, compute=t_comp, memory=t_mem, overhead=t_over,
                   bound=bound, notes=notes)


def softmax_latency(rows, cols, hw, block_rows=256, **_):
    return _rowwise_latency(rows, cols, hw, ops_per_elem=6.0, n_buffers=2,
                            block_rows=block_rows)


def rmsnorm_latency(rows, cols, hw, block_rows=256, **_):
    return _rowwise_latency(rows, cols, hw, ops_per_elem=4.0, n_buffers=2,
                            block_rows=block_rows)


def swiglu_latency(rows, cols, hw, block_rows=256, block_cols=512, **_):
    lat = _rowwise_latency(rows, min(cols, block_cols), hw, ops_per_elem=6.0,
                           n_buffers=3, block_rows=block_rows)
    if not lat.feasible:
        return lat
    scale = _ceil_div(cols, block_cols)
    return Latency(total=lat.total * scale, compute=lat.compute * scale,
                   memory=lat.memory * scale, overhead=lat.overhead * scale,
                   bound=lat.bound, notes=lat.notes)


def rope_latency(tokens, heads, dim, hw, block_tokens=128, **_):
    return _rowwise_latency(tokens, heads * dim, hw, ops_per_elem=8.0,
                            n_buffers=2, block_rows=block_tokens)


def attention_latency(bh, s, t, d, hw, block_q=128, block_k=128, *,
                      causal=True, window=0, scheme="bf16", **_):
    """flash attention: t_eff accounts for causal/window block skipping."""
    t_eff = t / 2 if causal and s == t else t
    if window and window > 0:
        t_eff = min(t_eff, window + block_k)
    vmem = (block_q * d * 4 * 2 + 2 * block_k * d * 4 + block_q * block_k * 4)
    if vmem > hw.fast_mem_bytes:
        return Latency(total=INFEASIBLE, feasible=False, bound="vmem",
                       notes="attention blocks exceed VMEM")
    flops = 4.0 * bh * s * t_eff * d
    t_comp = flops / hw.peak("bf16")
    traffic = bh * (s * d * 2 * 2 + 2 * t_eff * d * 2 * _ceil_div(s, block_q))
    t_mem = traffic / hw.mem_bw
    steps = bh * _ceil_div(s, block_q) * _ceil_div(t_eff, block_k)
    t_over = steps * hw.grid_step_overhead_s * 0.1
    total = max(t_comp, t_mem) + t_over
    return Latency(total=total, compute=t_comp, memory=t_mem, overhead=t_over,
                   bound="compute" if t_comp >= t_mem else "memory")


KERNEL_LATENCY = {
    "matmul": matmul_latency,
    "softmax": softmax_latency,
    "rmsnorm": rmsnorm_latency,
    "swiglu": swiglu_latency,
    "rope": rope_latency,
    "attention": attention_latency,
}


def kernel_latency(kernel: str, shape: Dict, hw: HardwareSpec,
                   config: Optional[Dict] = None, scheme: str = "bf16") -> Latency:
    fn = KERNEL_LATENCY[kernel]
    cfg = dict(config or {})
    if kernel == "matmul":
        return fn(shape["m"], shape["k"], shape["n"], hw, scheme=scheme, **cfg)
    if kernel in ("softmax", "rmsnorm"):
        return fn(shape["rows"], shape["cols"], hw, **cfg)
    if kernel == "swiglu":
        return fn(shape["rows"], shape["cols"], hw, **cfg)
    if kernel == "rope":
        return fn(shape["tokens"], shape["heads"], shape["dim"], hw, **cfg)
    if kernel == "attention":
        return fn(shape["bh"], shape["s"], shape["t"], shape["d"], hw,
                  scheme=scheme, **cfg)
    raise KeyError(kernel)


# ---------------------------------------------------------------------------
# end-to-end model latency (decode / prefill) and memory footprint
# ---------------------------------------------------------------------------

def model_weight_bytes(cfg: ModelConfig, scheme: str) -> float:
    _, wb, _, _, _ = _scheme_bytes(scheme)
    return cfg.param_count() * wb


def model_active_weight_bytes(cfg: ModelConfig, scheme: str) -> float:
    _, wb, _, _, _ = _scheme_bytes(scheme)
    return cfg.active_param_count() * wb


def kv_cache_bytes(cfg: ModelConfig, batch: int, context: int,
                   dtype_bytes: float = 2.0) -> float:
    total = 0.0
    hd = cfg.resolved_head_dim
    for i in range(cfg.num_layers):
        kind = cfg.layer_kind(i)
        if kind == "attn":
            size = min(cfg.window_size, context) if cfg.is_local_layer(i) else context
            total += 2 * batch * size * cfg.num_kv_heads * hd * dtype_bytes
        else:
            s = cfg.ssm
            if s:
                d_in = s.expand * cfg.d_model
                total += batch * d_in * s.d_state * 4 + batch * (s.d_conv - 1) * d_in * dtype_bytes
    return total


def decode_latency(cfg: ModelConfig, batch: int, context: int,
                   hw: HardwareSpec, scheme: str = "bf16",
                   n_chips: int = 1) -> Latency:
    """One-token decode step.  Weight + KV traffic dominate (memory-bound);
    emulation penalties apply per matmul."""
    xb, wb, ob, cdtype, weight_only = _scheme_bytes(scheme)
    w_bytes = model_active_weight_bytes(cfg, scheme) / n_chips
    kv_bytes = kv_cache_bytes(cfg, batch, context) / n_chips
    act_traffic = batch * cfg.num_layers * cfg.d_model * 8 * 2 / n_chips

    t_mem = (w_bytes + kv_bytes + act_traffic) / hw.mem_bw

    flops = 2.0 * batch * cfg.active_param_count() / n_chips
    flops += 4.0 * batch * cfg.num_layers * cfg.d_model * 8   # norms/rope/etc
    sup = hw.supports({"int8": "int8", "w8a8": "int8", "int4": "int4"}.get(scheme, "bf16"))
    peak = hw.peak("int8" if (scheme == "w8a8" and sup == Support.NATIVE) else cdtype)
    # achievable matvec fraction — encodes how well the deployment stack's
    # decode path uses the hardware for this scheme (calibrated, see hardware.py)
    peak = peak * hw.decode_eff(scheme)
    t_comp = flops / peak

    t_emul = 0.0
    if scheme == "int4" and hw.supports("int4") != Support.NATIVE:
        t_emul = 4.0 * (cfg.active_param_count() / n_chips) / hw.vector_ops
    elif weight_only:
        conv = 1.0 if hw.supports("int8") != Support.NATIVE else 0.5
        t_emul = conv * (cfg.active_param_count() / n_chips) / hw.vector_ops
    elif scheme == "w8a8" and sup != Support.NATIVE:
        t_emul = 2.0 * (cfg.active_param_count() / n_chips) / hw.vector_ops

    total = max(t_comp + t_emul, t_mem)
    bound = "compute" if (t_comp + t_emul) >= t_mem else "memory"
    notes = ""
    if t_emul > 0.3 * total:
        notes = (f"{scheme} emulation overhead ({t_emul*1e3:.2f} ms) negates its "
                 f"bandwidth savings on {hw.name}")
    return Latency(total=total, compute=t_comp, memory=t_mem,
                   emulation=t_emul, bound=bound, notes=notes)


def decode_throughput(cfg: ModelConfig, batch: int, context: int,
                      hw: HardwareSpec, scheme: str = "bf16",
                      n_chips: int = 1) -> float:
    """tokens/s for the whole batch."""
    lat = decode_latency(cfg, batch, context, hw, scheme, n_chips)
    return batch / lat.total if lat.total > 0 else 0.0


def prefill_latency(cfg: ModelConfig, batch: int, seq: int,
                    hw: HardwareSpec, scheme: str = "bf16",
                    n_chips: int = 1) -> Latency:
    xb, wb, ob, cdtype, weight_only = _scheme_bytes(scheme)
    tokens = batch * seq
    flops = 2.0 * tokens * cfg.active_param_count() / n_chips
    # attention quadratic term
    attn_layers = sum(1 for i in range(cfg.num_layers) if cfg.layer_kind(i) == "attn")
    hd = cfg.resolved_head_dim
    for i in range(cfg.num_layers):
        if cfg.layer_kind(i) != "attn":
            continue
        t_eff = min(seq, cfg.window_size) if cfg.is_local_layer(i) else seq / 2
        flops += 4.0 * batch * cfg.num_heads * seq * t_eff * hd / n_chips
    sup = hw.supports({"int8": "int8", "w8a8": "int8", "int4": "int4"}.get(scheme, "bf16"))
    peak = hw.peak("int8" if (scheme == "w8a8" and sup == Support.NATIVE) else cdtype)
    peak = peak * 0.55 if hw.kind == "tpu" else peak * 0.35   # prefill MFU
    t_comp = flops / peak
    t_emul = 0.0
    if scheme == "int4" and hw.supports("int4") != Support.NATIVE:
        # unpack once per weight tile visit; prefill reuses tiles across many
        # tokens, so charge once per weight element
        t_emul = 4.0 * cfg.active_param_count() / n_chips / hw.vector_ops
    w_traffic = model_active_weight_bytes(cfg, scheme) / n_chips
    act_traffic = tokens * cfg.num_layers * cfg.d_model * 6 * 2 / n_chips
    t_mem = (w_traffic + act_traffic) / hw.mem_bw
    total = max(t_comp + t_emul, t_mem)
    return Latency(total=total, compute=t_comp, memory=t_mem, emulation=t_emul,
                   bound="compute" if (t_comp + t_emul) >= t_mem else "memory")


def model_memory_gb(cfg: ModelConfig, scheme: str, batch: int = 1,
                    context: int = 2048, runtime_overhead_gb: float = 0.6) -> float:
    """Deployment memory footprint (Table 5 feasibility input)."""
    w = model_weight_bytes(cfg, scheme)
    kv = kv_cache_bytes(cfg, batch, context)
    act = batch * context * cfg.d_model * 2 * 4
    return (w + kv + act) / 2**30 + runtime_overhead_gb
