"""Trial history with the paper's §3.3 user-friendliness mechanics:
bounded length (context-overflow protection), task logs, best-trial tracking.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, List, Optional


@dataclasses.dataclass
class Trial:
    round: int
    config: Dict[str, Any]
    metrics: Dict[str, float]            # e.g. {"accuracy": .., "latency_us": ..}
    objective: float                     # scalar the optimizer maximizes
    thought: str = ""                    # agent's ReAct reasoning
    observation: str = ""                # evaluator feedback text
    losses: List[float] = dataclasses.field(default_factory=list)
    wall_s: float = 0.0
    failed: bool = False

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


class History:
    """Bounded trial log.

    Truncation keeps the most recent ``max_len`` trials plus the best trial —
    mirroring the paper's dynamic history-length control that prevents the
    agent's context from overflowing mid-run.
    """

    def __init__(self, max_len: int = 10):
        self.max_len = max_len
        self._trials: List[Trial] = []
        self.task_log: List[str] = []

    def append(self, trial: Trial) -> None:
        self._trials.append(trial)
        self.task_log.append(
            f"[round {trial.round}] config={json.dumps(trial.config, default=str)} "
            f"-> objective={trial.objective:.4f} metrics={json.dumps(trial.metrics)}")

    @property
    def trials(self) -> List[Trial]:
        return list(self._trials)

    def window(self) -> List[Trial]:
        """The bounded view the agent actually sees."""
        if len(self._trials) <= self.max_len:
            return list(self._trials)
        recent = self._trials[-self.max_len:]
        best = self.best()
        if best is not None and best not in recent:
            return [best] + recent[1:]
        return recent

    def best(self) -> Optional[Trial]:
        ok = [t for t in self._trials if not t.failed]
        return max(ok, key=lambda t: t.objective) if ok else None

    def last(self) -> Optional[Trial]:
        return self._trials[-1] if self._trials else None

    def __len__(self) -> int:
        return len(self._trials)

    def objectives(self) -> List[float]:
        return [t.objective for t in self._trials if not t.failed]

    def to_json(self) -> List[Dict]:
        return [t.to_json() for t in self._trials]

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"trials": self.to_json(), "task_log": self.task_log}, f,
                      indent=2, default=str)
