"""Typed hyperparameter search spaces (paper Appendix C/D).

A ``SearchSpace`` is an ordered dict of parameter specs.  It can sample,
validate, clamp, normalize (for the GP baseline) and render itself as the
paper's prompt text ("Type: UniformFloat, Range: [...], Default: ..., Log
scale").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class UniformFloat:
    name: str
    lo: float
    hi: float
    default: float
    log: bool = False
    doc: str = ""

    def sample(self, rng: np.random.Generator) -> float:
        if self.log:
            return float(np.exp(rng.uniform(math.log(self.lo), math.log(self.hi))))
        return float(rng.uniform(self.lo, self.hi))

    def clamp(self, v) -> float:
        return float(min(max(float(v), self.lo), self.hi))

    def valid(self, v) -> bool:
        try:
            return self.lo <= float(v) <= self.hi
        except (TypeError, ValueError):
            return False

    def normalize(self, v) -> float:
        if self.log:
            return (math.log(float(v)) - math.log(self.lo)) / (math.log(self.hi) - math.log(self.lo))
        return (float(v) - self.lo) / (self.hi - self.lo)

    def denormalize(self, u: float) -> float:
        u = min(max(u, 0.0), 1.0)
        if self.log:
            return float(math.exp(math.log(self.lo) + u * (math.log(self.hi) - math.log(self.lo))))
        return float(self.lo + u * (self.hi - self.lo))

    def prompt_line(self) -> str:
        log = ", Log scale" if self.log else ""
        return (f"'{self.name}': {self.doc} Type: UniformFloat, "
                f"Range: [{self.lo}, {self.hi}], Default: {self.default}{log}.")


@dataclasses.dataclass(frozen=True)
class UniformInt:
    name: str
    lo: int
    hi: int
    default: int
    log: bool = False
    doc: str = ""

    def sample(self, rng: np.random.Generator) -> int:
        if self.log:
            return int(round(np.exp(rng.uniform(math.log(self.lo), math.log(self.hi)))))
        return int(rng.integers(self.lo, self.hi + 1))

    def clamp(self, v) -> int:
        return int(min(max(int(round(float(v))), self.lo), self.hi))

    def valid(self, v) -> bool:
        try:
            return self.lo <= int(v) <= self.hi and float(v) == int(v)
        except (TypeError, ValueError):
            return False

    def normalize(self, v) -> float:
        if self.log:
            return (math.log(float(v)) - math.log(self.lo)) / (math.log(self.hi) - math.log(self.lo))
        return (float(v) - self.lo) / max(self.hi - self.lo, 1)

    def denormalize(self, u: float) -> int:
        u = min(max(u, 0.0), 1.0)
        if self.log:
            return int(round(math.exp(math.log(self.lo) + u * (math.log(self.hi) - math.log(self.lo)))))
        return int(round(self.lo + u * (self.hi - self.lo)))

    def prompt_line(self) -> str:
        log = ", Log scale" if self.log else ""
        return (f"'{self.name}': {self.doc} Type: UniformInteger, "
                f"Range: [{self.lo}, {self.hi}], Default: {self.default}{log}.")


@dataclasses.dataclass(frozen=True)
class Categorical:
    name: str
    choices: Tuple[Any, ...]
    default: Any
    doc: str = ""

    def sample(self, rng: np.random.Generator):
        return self.choices[int(rng.integers(0, len(self.choices)))]

    def clamp(self, v):
        if v in self.choices:
            return v
        # snap numeric values to the nearest choice
        try:
            fv = float(v)
            return min(self.choices, key=lambda c: abs(float(c) - fv))
        except (TypeError, ValueError):
            return self.default

    def valid(self, v) -> bool:
        return v in self.choices or (isinstance(v, list) and tuple(v) in self.choices)

    def normalize(self, v) -> float:
        try:
            return self.choices.index(v) / max(len(self.choices) - 1, 1)
        except ValueError:
            return 0.0

    def denormalize(self, u: float):
        idx = int(round(min(max(u, 0.0), 1.0) * (len(self.choices) - 1)))
        return self.choices[idx]

    def prompt_line(self) -> str:
        return (f"'{self.name}': {self.doc} Type: Categorical, "
                f"Choices: {list(self.choices)}, Default: {self.default}.")


ParamSpec = Any  # UniformFloat | UniformInt | Categorical


class SearchSpace:
    def __init__(self, specs: Sequence[ParamSpec], name: str = "space"):
        self.name = name
        self.specs: Dict[str, ParamSpec] = {s.name: s for s in specs}

    @property
    def names(self) -> List[str]:
        return list(self.specs)

    def defaults(self) -> Dict[str, Any]:
        return {n: s.default for n, s in self.specs.items()}

    def sample(self, rng: np.random.Generator) -> Dict[str, Any]:
        return {n: s.sample(rng) for n, s in self.specs.items()}

    def validate(self, config: Dict[str, Any]) -> List[str]:
        """Returns list of violation messages (paper §3.2 issues 2 & 3)."""
        errs = []
        for n in config:
            if n not in self.specs:
                errs.append(f"unknown parameter '{n}' (irrelevant to the task)")
        for n, s in self.specs.items():
            if n not in config:
                errs.append(f"missing parameter '{n}'")
            elif not s.valid(config[n]):
                errs.append(f"'{n}'={config[n]!r} outside range")
        return errs

    def clamp(self, config: Dict[str, Any]) -> Dict[str, Any]:
        out = {}
        for n, s in self.specs.items():
            out[n] = s.clamp(config[n]) if n in config else s.default
        return out

    def normalize(self, config: Dict[str, Any]) -> np.ndarray:
        return np.array([self.specs[n].normalize(config[n]) for n in self.names])

    def denormalize(self, u: np.ndarray) -> Dict[str, Any]:
        return {n: self.specs[n].denormalize(float(u[i]))
                for i, n in enumerate(self.names)}

    def prompt_text(self) -> str:
        return "\n".join(s.prompt_line() for s in self.specs.values())

    def size_estimate(self) -> float:
        """log10 of the Cartesian-product cardinality (continuous ~ 100 steps)."""
        total = 0.0
        for s in self.specs.values():
            if isinstance(s, Categorical):
                total += math.log10(len(s.choices))
            elif isinstance(s, UniformInt):
                total += math.log10(max(s.hi - s.lo + 1, 1))
            else:
                total += 2.0
        return total


# ---------------------------------------------------------------------------
# the paper's spaces (Appendix C/D + prompt samples)
# ---------------------------------------------------------------------------

def llama_finetune_space() -> SearchSpace:
    return SearchSpace([
        UniformFloat("learning_rate", 1e-5, 1e-3, 4e-4, log=True,
                     doc="Learning rate for the optimizer."),
        UniformInt("per_device_train_batch_size", 4, 16, 8,
                   doc="Batch size for per-device training."),
        UniformInt("gradient_accumulation_steps", 4, 32, 8,
                   doc="Number of steps for gradient accumulation."),
        UniformFloat("weight_decay", 1e-3, 1e-1, 1e-2, log=True,
                     doc="L2 regularization coefficient."),
        UniformInt("max_steps", 200, 1000, 400,
                   doc="Maximum number of steps for training."),
        UniformFloat("max_grad_norm", 0.1, 1.0, 0.3,
                     doc="Maximum norm for gradient clipping."),
        UniformInt("lora_r", 8, 64, 16, doc="Rank parameter for LoRA."),
        UniformInt("lora_alpha", 4, 32, 8, doc="Alpha parameter for LoRA."),
        UniformFloat("lora_dropout", 0.0, 0.3, 0.05,
                     doc="Dropout probability for LoRA."),
        UniformFloat("warmup_ratio", 0.0, 0.08, 0.03, doc="warmup_ratio."),
    ], name="llama_qlora_finetune")


def resnet_finetune_space() -> SearchSpace:
    return SearchSpace([
        UniformFloat("learning_rate", 1e-5, 0.2, 0.01, log=True,
                     doc="The learning rate for the optimizer."),
        UniformInt("batch_size", 32, 256, 128, log=True,
                   doc="The number of samples per batch of input data."),
        UniformFloat("weight_decay", 1e-6, 0.1, 5e-4, log=True,
                     doc="The L2 regularization coefficient."),
        UniformFloat("momentum", 0.5, 0.99, 0.9,
                     doc="The momentum for the SGD optimizer."),
        UniformInt("num_epochs", 8, 12, 12,
                   doc="The number of training epochs."),
    ], name="resnet_dorefa_qat")


def deploy_space(kernel: str) -> SearchSpace:
    """Deployment space for one kernel (TPU analogue of App D's end-to-end
    deployment search: tiles/parallelization/unroll/layout)."""
    from repro.kernels import registry as kreg
    info = kreg.KERNELS[kernel]
    specs = []
    for field, choices in info.space.items():
        if field == "dimension_semantics":
            specs.append(Categorical("dimension_semantics", tuple(choices),
                                     choices[0],
                                     doc="Mosaic grid-dimension semantics "
                                         "(pipelining/parallelization)."))
        else:
            specs.append(Categorical(field, tuple(choices),
                                     getattr(info.config_cls(), field),
                                     doc=f"{kernel} {field} tile."))
    return SearchSpace(specs, name=f"deploy_{kernel}")


def serve_space() -> SearchSpace:
    """Serving-deployment knobs for the HAQA loop (Table-3 style): the
    speculative-decode schedule, the paged-KV pool geometry (page size and
    pool fraction — the per-platform memory knob a hardware-aware agent
    tunes against the device's HBM budget: a smaller pool admits the same
    traffic in less memory at the cost of evictions), the prefix-cache
    budget (cache fraction and minimum shareable prefix — prefill skipped
    vs pool headroom), and the flash-decode / flash-verify kernel
    tiles.  These are exactly the counterintuitive,
    hardware-dependent knobs the paper's agent is built to tune — the
    optimal draft length trades verify-step arithmetic intensity against
    acceptance rate, and the optimal split-K point moves with it."""
    from repro.kernels import registry as kreg
    fd = kreg.KERNELS["flash_decode"].space
    fv = kreg.KERNELS["flash_verify"].space
    pd = kreg.KERNELS["paged_flash_decode"].space
    return SearchSpace([
        UniformInt("spec_len", 0, 8, 4,
                   doc="Draft tokens proposed per speculative verify step "
                       "(0 disables speculation)."),
        Categorical("draft_mode", ("none", "ngram", "model"), "ngram",
                    doc="Speculative draft source: model-free n-gram table "
                        "from the prompt, or a small draft model."),
        UniformInt("macro_steps", 1, 32, 8,
                   doc="Decode steps fused per on-device macro-step."),
        Categorical("page_size", pd["page_size"], 64,
                    doc="Paged-KV pool page size in rows (block-table "
                        "granularity; smaller pages waste less memory per "
                        "slot but widen the table and shrink kernel "
                        "tiles)."),
        UniformFloat("kv_pool_frac", 0.25, 1.0, 1.0,
                     doc="Paged-KV pool size as a fraction of the "
                         "contiguous layout's worst-case reservation "
                         "(max_batch x max_len rows); below 1.0 the engine "
                         "over-commits slots and relies on eviction+requeue "
                         "under pressure."),
        UniformFloat("prefix_cache_frac", 0.0, 1.0, 0.5,
                     doc="Fraction of the paged-KV pool that may be "
                         "registered in the prefix index (shared system "
                         "prompts / templates; floored at one page when "
                         "nonzero); 0 disables the prefix cache "
                         "entirely.  Trades pool headroom for skipped "
                         "prefill — the right point depends on the "
                         "platform's HBM budget and the traffic's prefix "
                         "reuse."),
        UniformInt("min_shared_pages", 1, 8, 1,
                   doc="Smallest cached prefix (in pages) worth mapping at "
                       "admission; short matches save little prefill but "
                       "still pin pages and pay table bookkeeping."),
        UniformFloat("host_tier_frac", 0.0, 4.0, 1.0,
                     doc="Host-memory KV-tier budget as a fraction of the "
                         "device pool (0 disables tiering).  Preempted "
                         "slots swap committed pages to host instead of "
                         "losing them and dropped prefix pages spill there "
                         "before eviction — requeue/re-admission swaps "
                         "pages back in, skipping re-prefill at the cost "
                         "of host RAM and PCIe traffic; the right budget "
                         "is a per-platform call (host RAM vs recompute "
                         "FLOPs) the hardware-aware agent makes."),
        Categorical("flash_decode_block_k", fd["block_k"], 128,
                    doc="flash_decode key-block tile."),
        Categorical("flash_decode_k_splits", fd["k_splits"], 4,
                    doc="flash_decode split-K factor."),
        Categorical("flash_verify_block_k", fv["block_k"], 128,
                    doc="flash_verify key-block tile."),
        Categorical("flash_verify_k_splits", fv["k_splits"], 4,
                    doc="flash_verify split-K factor."),
        UniformFloat("deadline_ms", 0.0, 60000.0, 0.0,
                     doc="Default per-request total wall-clock deadline in "
                         "ms (0 disables); expired requests release their "
                         "slot with finish_reason='deadline'.  The SLO half "
                         "of the robustness/throughput frontier: tight "
                         "deadlines bound tail latency but waste the work "
                         "already spent on expired requests."),
        UniformFloat("ladder_spec_util", 0.5, 1.0, 0.85,
                     doc="Pool-utilization fraction above which the "
                         "degradation ladder's first rung fires: shrink the "
                         "speculative draft to its L=1 probe so each "
                         "macro-step grows the KV footprint by at most one "
                         "row per slot."),
        UniformFloat("ladder_spill_util", 0.5, 1.0, 0.88,
                     doc="Spill rung (between draft-shrink and "
                         "admit-throttle): drop LRU-parked cached pages to "
                         "the free list, spilling their contents to the "
                         "host KV tier so the prefixes stay matchable — "
                         "free-list headroom is bought with host memory "
                         "and a possible swap-in later, not with lost "
                         "prefill work."),
        UniformFloat("ladder_admit_util", 0.5, 1.0, 0.92,
                     doc="Second rung: throttle chunked-prefill admission "
                         "to one slot per scheduler iteration, keeping "
                         "decode progress ahead of new-page demand."),
        UniformFloat("ladder_prefix_util", 0.5, 1.0, 0.96,
                     doc="Third rung: stop prefix-cache admissions (no new "
                         "registrations or matches) so every reclaimable "
                         "LRU page stays reclaimable."),
        UniformFloat("ladder_reject_util", 0.5, 1.0, 1.0,
                     doc="Last rung: reject FRESH requests with a "
                         "backpressure error (finish_reason='rejected') "
                         "instead of admitting work the pool cannot hold; "
                         "requests with progress (preempted/quarantined) "
                         "are never backpressure-rejected."),
        UniformInt("cluster_workers", 1, 8, 2,
                   doc="Replicated engine workers behind the cluster "
                       "router.  Fleet sizing is the canonical "
                       "hardware-aware knob: more workers buy decode "
                       "parallelism and failover headroom, but split the "
                       "per-worker KV pool and dilute prefix-cache "
                       "locality."),
        Categorical("cluster_router", ("affinity", "least_loaded",
                                       "round_robin"), "affinity",
                    doc="Request router policy: prefix-affinity (route "
                        "shared-prefix traffic to the worker that served "
                        "the prefix last, falling back to least-loaded), "
                        "pure least-loaded, or round-robin.  Affinity wins "
                        "on system-prompt-heavy traffic; least-loaded wins "
                        "when prompts share nothing."),
        UniformFloat("cluster_watchdog_s", 0.5, 300.0, 120.0,
                     doc="Hung-macro-step watchdog: a busy worker whose "
                         "heartbeat (scheduler-iteration progress) goes "
                         "stale this long is declared hung and failed "
                         "over.  Tight budgets bound hang detection "
                         "latency but false-positive on slow hardware or "
                         "cold jit compiles."),
        UniformInt("cluster_retry_budget", 0, 5, 2,
                   doc="Redispatch attempts per request after worker "
                       "failures before it is committed with "
                       "finish_reason='failed_over'; 0 fails over "
                       "immediately on first loss."),
        UniformFloat("cluster_hedge_ms", 0.0, 60000.0, 0.0,
                     doc="Hedged-dispatch threshold: a dispatch still "
                         "running after this many ms is duplicated onto "
                         "an idle healthy worker (uid dedup keeps results "
                         "exactly-once); 0 disables hedging.  Trades tail "
                         "latency for duplicated decode work."),
        UniformFloat("cluster_breaker_cooldown_s", 0.05, 60.0, 0.25,
                     doc="Circuit-breaker open->half-open cooldown: how "
                         "long a failed worker sits out before it is "
                         "rebuilt (warm from its checkpoint when "
                         "possible) and probed with one dispatch."),
    ], name="serve_deploy")


def bitwidth_space() -> SearchSpace:
    return SearchSpace([
        Categorical("quant_scheme", ("fp16", "int8", "int4"), "int8",
                    doc="Deployment quantization bit-width."),
    ], name="bitwidth")
