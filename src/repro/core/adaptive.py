"""Adaptive quantization strategy selection (paper §3.4/§4.4, Appendix F).

Combines the hardware descriptor (dtype support levels, accelerator notes)
with the cost model's predicted throughput, and emits the decision *with the
reasoning trace* — including the counter-intuitive cases: INT8 over INT4 on
devices whose int4 path is emulated (OnePlus 11 / Adreno 740 in the paper;
natively reproduced by the TPU's missing int4 MXU path).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.configs.base import ModelConfig
from repro.core import costmodel, memory_planner
from repro.core.hardware import HardwareSpec, Support


@dataclasses.dataclass
class Decision:
    scheme: str
    throughput_tps: float
    footprint_gb: float
    counterintuitive: bool
    thought: str
    ranking: List[memory_planner.PlanEntry]


def choose_quantization(cfg: ModelConfig, hw: HardwareSpec,
                        memory_limit_gb: Optional[float] = None,
                        batch: int = 1, context: int = 2048,
                        workload: str = "decode") -> Decision:
    limit = memory_limit_gb if memory_limit_gb is not None else hw.memory_gb
    entries = memory_planner.plan(cfg, limit, hw, batch, context)
    feasible = [e for e in entries if e.fits]
    if not feasible:
        return Decision("none", 0.0, 0.0, False,
                        thought=(f"No quantization type fits the {limit} GB "
                                 f"limit for {cfg.name}; the smallest footprint "
                                 f"is {min(e.footprint_gb for e in entries):.1f} GB (int4). "
                                 "Reject deployment on this device."),
                        ranking=entries)

    if workload == "prefill":
        scored = [(e, 1.0 / max(costmodel.prefill_latency(
            cfg, batch, context, hw, e.scheme).total, 1e-9)) for e in feasible]
        best, _ = max(scored, key=lambda p: p[1])
    else:
        best = max(feasible, key=lambda e: e.throughput_tps)

    naive = min(feasible, key=lambda e: {"int4": 0, "int8": 1, "fp16": 2}[e.scheme])
    counterintuitive = best.scheme != naive.scheme

    thought = _narrate(cfg, hw, best, naive, counterintuitive, workload)
    return Decision(best.scheme, best.throughput_tps, best.footprint_gb,
                    counterintuitive, thought, entries)


def _narrate(cfg, hw, best, naive, counterintuitive, workload) -> str:
    lines = [f"For {cfg.name} on {hw.name} ({workload}):"]
    int4_sup = hw.supports("int4")
    int8_sup = hw.supports("int8")
    if counterintuitive and naive.scheme == "int4":
        if int4_sup != Support.NATIVE:
            lines.append(
                "Although INT4 has the smallest footprint and is generally "
                "assumed fastest, this device does not natively support INT4 "
                f"({hw.notes}). INT4 values must be unpacked with extra "
                "bitwise operations and converted before the matrix unit, so "
                "INT4 fails to trigger the optimized execution path and falls "
                "back to general-purpose computation.")
        lines.append(
            f"The best choice is {best.scheme.upper()}: predicted "
            f"{best.throughput_tps:.2f} tok/s vs {naive.throughput_tps:.2f} "
            f"tok/s for {naive.scheme.upper()}.")
    else:
        if best.scheme == "int4":
            lines.append(
                "Decode is memory-bandwidth-bound: INT4 halves weight traffic "
                "relative to INT8, and the unpack cost stays hidden under the "
                "HBM transfers, so INT4 gives the highest generation speed.")
        elif best.scheme == "int8" and int8_sup == Support.NATIVE:
            lines.append(
                "INT8 is natively accelerated here (matrix unit consumes int8 "
                "directly at double throughput), giving the best "
                "speed/accuracy/memory balance.")
        lines.append(f"Selected {best.scheme.upper()} at predicted "
                     f"{best.throughput_tps:.2f} tok/s, "
                     f"{best.footprint_gb:.1f} GB.")
    return " ".join(lines)
