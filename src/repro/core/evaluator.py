"""Trial evaluators: the Observation half of the HAQA loop.

``KernelEvaluator``   — kernel deployment configs scored by the analytical
                        TPU cost model (lower latency = higher objective).
``DecodeEvaluator``   — end-to-end decode throughput for bit-width selection.
``FinetuneEvaluator`` — wraps a real (small-scale) training function.

All evaluators support straggler/failure injection (timeout_prob) with
bounded retries — the fault-tolerance path a 1000-node fleet needs when an
agent round's trial lands on a bad host.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import costmodel
from repro.core.agent import EvalResult
from repro.core.hardware import HardwareSpec


@dataclasses.dataclass
class FaultInjection:
    timeout_prob: float = 0.0       # chance a trial "straggles"/fails
    max_retries: int = 2
    seed: int = 1234

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)


def _with_retries(fn, fault: Optional[FaultInjection]):
    if fault is None or fault.timeout_prob <= 0:
        return fn(), 0
    for attempt in range(fault.max_retries + 1):
        if fault.rng.random() >= fault.timeout_prob:
            return fn(), attempt
    raise TimeoutError("trial exceeded straggler deadline after retries")


class KernelEvaluator:
    """Score a kernel config: objective = -log(latency)."""

    def __init__(self, kernel: str, shape: Dict, hw: HardwareSpec,
                 scheme: str = "bf16", fault: Optional[FaultInjection] = None):
        self.kernel = kernel
        self.shape = shape
        self.hw = hw
        self.scheme = scheme
        self.fault = fault

    def __call__(self, config: Dict[str, Any]) -> EvalResult:
        cfg = dict(config)
        if isinstance(cfg.get("dimension_semantics"), list):
            cfg["dimension_semantics"] = tuple(cfg["dimension_semantics"])

        def run():
            return costmodel.kernel_latency(self.kernel, self.shape, self.hw,
                                            cfg, self.scheme)

        lat, retries = _with_retries(run, self.fault)
        if not lat.feasible:
            return EvalResult(
                metrics={"latency_us": float("inf"), "feasible": 0.0},
                objective=float("-inf"),
                observation=lat.notes or "infeasible configuration",
                failed=False,
                feedback={"feasible": False, "bound": lat.bound,
                          "notes": lat.notes})
        us = lat.total * 1e6
        obs = (f"Latency: {us:.3f} us ({lat.bound}-bound; compute "
               f"{lat.compute*1e6:.2f} us, memory {lat.memory*1e6:.2f} us, "
               f"overhead {lat.overhead*1e6:.2f} us"
               + (f", emulation {lat.emulation*1e6:.2f} us" if lat.emulation else "")
               + (f"). {lat.notes}" if lat.notes else ")."))
        return EvalResult(
            metrics={"latency_us": us, "feasible": 1.0,
                     "retries": float(retries)},
            objective=-float(np.log(max(us, 1e-6))),
            observation=obs,
            feedback={"feasible": True, "bound": lat.bound, "notes": lat.notes})


class DecodeEvaluator:
    """Score a {'quant_scheme': ...} config by decode throughput under a
    memory limit (bit-width selection)."""

    def __init__(self, cfg: ModelConfig, hw: HardwareSpec, batch: int = 1,
                 context: int = 2048, memory_limit_gb: Optional[float] = None,
                 fault: Optional[FaultInjection] = None):
        self.cfg = cfg
        self.hw = hw
        self.batch = batch
        self.context = context
        self.limit = memory_limit_gb if memory_limit_gb is not None else hw.memory_gb
        self.fault = fault

    def __call__(self, config: Dict[str, Any]) -> EvalResult:
        scheme = config.get("quant_scheme", "fp16")
        gb = costmodel.model_memory_gb(self.cfg, scheme, self.batch, self.context)
        if gb > self.limit:
            return EvalResult(
                metrics={"footprint_gb": gb, "fits": 0.0},
                objective=float("-inf"),
                observation=(f"{scheme} needs {gb:.1f} GB, exceeding the "
                             f"{self.limit} GB limit — rejected."))

        def run():
            return costmodel.decode_throughput(self.cfg, self.batch,
                                               self.context, self.hw, scheme)

        tput, retries = _with_retries(run, self.fault)
        lat = costmodel.decode_latency(self.cfg, self.batch, self.context,
                                       self.hw, scheme)
        return EvalResult(
            metrics={"throughput_tps": tput, "footprint_gb": gb, "fits": 1.0,
                     "latency_us": lat.total * 1e6},
            objective=tput,
            observation=(f"{scheme}: {tput:.2f} tok/s, {gb:.1f} GB "
                         f"({lat.bound}-bound). {lat.notes}"))


class FinetuneEvaluator:
    """Wraps a real training run: ``train_fn(config) -> (metrics, losses)``.

    metrics must contain task accuracies; objective = their mean ("AVG" in
    the paper's Table 2).
    """

    def __init__(self, train_fn: Callable[[Dict], Any],
                 fault: Optional[FaultInjection] = None):
        self.train_fn = train_fn
        self.fault = fault

    def __call__(self, config: Dict[str, Any]) -> EvalResult:
        def run():
            return self.train_fn(config)

        (metrics, losses), retries = _with_retries(run, self.fault)
        finite = [v for v in metrics.values() if np.isfinite(v)]
        if not finite or any(not np.isfinite(l) for l in losses):
            return EvalResult(metrics=metrics, objective=float("-inf"),
                              observation="training diverged (non-finite loss)",
                              losses=list(losses), failed=True)
        avg = float(np.mean(finite))
        obs = "Evaluation Result: " + ", ".join(
            f"{k}: {v:.4f}" for k, v in metrics.items())
        return EvalResult(metrics={**metrics, "avg": avg}, objective=avg,
                          observation=obs, losses=list(losses))
