"""granite-3-2b — dense GQA decoder.
[hf:ibm-granite/granite-3.0-2b-base; hf]"""
from repro.configs.base import ModelConfig

ARCH_ID = "granite-3-2b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49_155,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-2b-base; hf",
)

SMOKE = ModelConfig(
    name=ARCH_ID + "-smoke",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=8,
    d_ff=192,
    vocab_size=387,               # deliberately non-multiple of 256 (padding path)
    tie_embeddings=True,
)
