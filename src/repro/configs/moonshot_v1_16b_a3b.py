"""moonshot-v1-16b-a3b (Moonlight-16B-A3B) — deepseek-style fine-grained MoE,
64 routed top-6 + shared experts. [hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

ARCH_ID = "moonshot-v1-16b-a3b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,               # MHA
    head_dim=128,
    d_ff=1408,
    vocab_size=163_840,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408, num_shared=2,
                  first_dense=1, d_ff_dense=11264),
    tie_embeddings=False,
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
)

SMOKE = ModelConfig(
    name=ARCH_ID + "-smoke",
    family="moe",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=32,
    vocab_size=640,
    moe=MoEConfig(num_experts=8, top_k=3, d_ff_expert=32, num_shared=2,
                  first_dense=1, d_ff_dense=96),
    tie_embeddings=False,
)
