"""The paper's own evaluation models (Tables 1-5): LLaMA family + a ~100M
example model for the end-to-end driver."""
from repro.configs.base import ModelConfig

LLAMA2_7B = ModelConfig(
    name="llama2-7b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=32, head_dim=128, d_ff=11008,
    vocab_size=32_000, tie_embeddings=False, source="arXiv:2307.09288")

LLAMA2_13B = ModelConfig(
    name="llama2-13b", family="dense", num_layers=40, d_model=5120,
    num_heads=40, num_kv_heads=40, head_dim=128, d_ff=13824,
    vocab_size=32_000, tie_embeddings=False, source="arXiv:2307.09288")

LLAMA3_8B = ModelConfig(
    name="llama3-8b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=14336,
    vocab_size=128_256, rope_theta=500_000.0, tie_embeddings=False,
    source="arXiv:2407.21783")

LLAMA32_3B = ModelConfig(
    name="llama3.2-3b", family="dense", num_layers=28, d_model=3072,
    num_heads=24, num_kv_heads=8, head_dim=128, d_ff=8192,
    vocab_size=128_256, rope_theta=500_000.0, tie_embeddings=True,
    source="hf:meta-llama/Llama-3.2-3B")

# ~100M-parameter llama-style model for the end-to-end training example
TINY_100M = ModelConfig(
    name="tiny-100m", family="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
    vocab_size=32_000, tie_embeddings=True, source="examples")

# pocket model for tests/quickstart (sub-second init on CPU)
POCKET = ModelConfig(
    name="pocket", family="dense", num_layers=4, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=384,
    vocab_size=512, tie_embeddings=True, source="tests")
