"""falcon-mamba-7b — pure Mamba-1 architecture, attention-free.
[arXiv:2410.05355; unverified]

HAQA arch-applicability note (DESIGN.md §Arch-applicability): the paper's
softmax/RoPE kernel-tuning sub-spaces do not apply (no attention); the agent
tunes qmatmul/rmsnorm/ssm kernels and quantization bit-widths instead.
"""
from repro.configs.base import ModelConfig, SSMConfig

ARCH_ID = "falcon-mamba-7b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,                        # mamba block only, no MLP
    vocab_size=65_024,
    attn_pattern="none",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    tie_embeddings=False,
    sub_quadratic=True,
    source="arXiv:2410.05355; unverified",
)

SMOKE = ModelConfig(
    name=ARCH_ID + "-smoke",
    family="ssm",
    num_layers=4,
    d_model=64,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    attn_pattern="none",
    ssm=SSMConfig(d_state=8, d_conv=4, expand=2),
    tie_embeddings=False,
    sub_quadratic=True,
)
