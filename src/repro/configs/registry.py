"""Architecture registry: ``--arch <id>`` resolution for launchers/tests."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, shape_applicable

_ARCH_MODULES = [
    "jamba_1_5_large_398b",
    "musicgen_large",
    "gemma2_27b",
    "command_r_35b",
    "granite_3_2b",
    "granite_8b",
    "deepseek_moe_16b",
    "moonshot_v1_16b_a3b",
    "falcon_mamba_7b",
    "qwen2_vl_72b",
]


def _load():
    configs: Dict[str, ModelConfig] = {}
    smokes: Dict[str, ModelConfig] = {}
    for mod_name in _ARCH_MODULES:
        mod = importlib.import_module(f"repro.configs.{mod_name}")
        configs[mod.ARCH_ID] = mod.CONFIG
        smokes[mod.ARCH_ID] = mod.SMOKE
    from repro.configs import paper_models as pm
    for cfg in [pm.LLAMA2_7B, pm.LLAMA2_13B, pm.LLAMA3_8B, pm.LLAMA32_3B,
                pm.TINY_100M, pm.POCKET]:
        configs[cfg.name] = cfg
    return configs, smokes


_CONFIGS, _SMOKES = _load()
ASSIGNED_ARCHS: List[str] = [
    "jamba-1.5-large-398b", "musicgen-large", "gemma2-27b", "command-r-35b",
    "granite-3-2b", "granite-8b", "deepseek-moe-16b", "moonshot-v1-16b-a3b",
    "falcon-mamba-7b", "qwen2-vl-72b",
]


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _CONFIGS:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(_CONFIGS)}")
    return _CONFIGS[arch_id]


def get_smoke_config(arch_id: str) -> ModelConfig:
    if arch_id not in _SMOKES:
        raise KeyError(f"no smoke config for '{arch_id}'")
    return _SMOKES[arch_id]


def get_shape(shape_id: str) -> ShapeConfig:
    if shape_id not in SHAPES:
        raise KeyError(f"unknown shape '{shape_id}'; known: {sorted(SHAPES)}")
    return SHAPES[shape_id]


def list_archs() -> List[str]:
    return sorted(_CONFIGS)


def all_cells(include_skips: bool = False):
    """All (arch, shape) dry-run cells; skipped ones flagged."""
    cells = []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok = shape_applicable(cfg, shape)
            if ok or include_skips:
                cells.append((arch, shape.name, ok))
    return cells
