"""gemma2-27b — dense, local/global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig

ARCH_ID = "gemma2-27b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256_000,
    attn_pattern="local_global",
    window_size=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    tie_embeddings=True,
    source="arXiv:2408.00118; hf",
)

SMOKE = ModelConfig(
    name=ARCH_ID + "-smoke",
    family="dense",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    attn_pattern="local_global",
    window_size=8,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    tie_embeddings=True,
)
