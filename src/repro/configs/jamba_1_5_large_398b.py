"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887 / 2408.12570; hf]"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

ARCH_ID = "jamba-1.5-large-398b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    attn_pattern="hybrid_1_7",
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576, every=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    use_rope=False,                   # Jamba uses no positional encoding
    tie_embeddings=True,
    sub_quadratic=True,               # 63/72 layers are Mamba -> long_500k runs
    source="arXiv:2403.19887; hf",
)

SMOKE = ModelConfig(
    name=ARCH_ID + "-smoke",
    family="hybrid",
    num_layers=8,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=257,
    attn_pattern="hybrid_1_7",
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128, every=2),
    ssm=SSMConfig(d_state=8, d_conv=4, expand=2),
    use_rope=False,
    tie_embeddings=True,
    sub_quadratic=True,
)
