"""command-r-35b — dense GQA decoder, no biases, tied embeddings.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ModelConfig

ARCH_ID = "command-r-35b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256_000,
    rope_theta=8_000_000.0,
    tie_embeddings=True,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)

SMOKE = ModelConfig(
    name=ARCH_ID + "-smoke",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=8,
    d_ff=192,
    vocab_size=512,
    rope_theta=8_000_000.0,
    tie_embeddings=True,
)
