"""musicgen-large — decoder-only transformer over EnCodec audio tokens.
[arXiv:2306.05284; hf]  The EnCodec frontend is a stub: input_specs() supplies
the token ids it would produce (see repro.models.frontends).

Adaptation note: the original uses learned sinusoidal positions; we use RoPE
as the shared backbone convention (recorded in DESIGN.md).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "musicgen-large"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,              # MHA
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,              # EnCodec codebook
    frontend="audio_frames",
    tie_embeddings=True,
    source="arXiv:2306.05284; hf",
)

SMOKE = ModelConfig(
    name=ARCH_ID + "-smoke",
    family="audio",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    frontend="audio_frames",
    tie_embeddings=True,
)
