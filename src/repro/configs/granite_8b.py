"""granite-8b — llama-arch code model, GQA.
[arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig

ARCH_ID = "granite-8b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49_152,
    tie_embeddings=False,          # llama-style untied head
    source="arXiv:2405.04324; hf",
)

SMOKE = ModelConfig(
    name=ARCH_ID + "-smoke",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=16,
    d_ff=192,
    vocab_size=512,
    tie_embeddings=False,
)
