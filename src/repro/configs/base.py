"""Model / shape configuration dataclasses and the arch registry."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    capacity_factor: float = 1.25
    every: int = 1                 # MoE replaces the MLP every Nth layer
    first_dense: int = 0           # leading dense layers (deepseek-moe style)
    d_ff_dense: int = 0            # dense-MLP width for non-MoE layers


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0               # 0 -> ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                 # 0 for attention-free
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_pattern: str = "global"   # global | local_global | hybrid_1_7 | none
    window_size: int = 4096
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    rope_theta: float = 10_000.0
    rope_mode: str = "standard"    # standard | mrope
    use_rope: bool = True          # Jamba: no positional encoding
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    frontend: str = "none"         # none | audio_frames | vision_patches
    sub_quadratic: bool = False    # eligible for long_500k
    kv_cache_dtype: str = "bf16"   # bf16 | int8 (quantized KV, §Perf)
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    def param_count(self) -> int:
        """Approximate total parameter count (for roofline MODEL_FLOPS)."""
        d, L, v = self.d_model, self.num_layers, self.vocab_size
        total = v * d                                     # embeddings
        if not self.tie_embeddings:
            total += v * d
        per_layer_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        for i in range(L):
            kind = self.layer_kind(i)
            if kind == "attn":
                total += per_layer_attn
            elif kind == "mamba":
                s = self.ssm or SSMConfig()
                d_in = s.expand * d
                dt_rank = s.dt_rank or -(-d // 16)
                total += 2 * d * d_in            # in_proj (x and z)
                total += d_in * s.d_conv         # conv
                total += d_in * (dt_rank + 2 * s.d_state)   # x_proj
                total += dt_rank * d_in + d_in   # dt_proj
                total += d_in * s.d_state + d_in  # A_log, D
                total += d_in * d                # out_proj
            total += self.mlp_params(i)
            total += 2 * d                       # norms
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed top-k + shared)."""
        d, L, v = self.d_model, self.num_layers, self.vocab_size
        total = v * d
        if not self.tie_embeddings:
            total += v * d
        per_layer_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        for i in range(L):
            kind = self.layer_kind(i)
            if kind == "attn":
                total += per_layer_attn
            elif kind == "mamba":
                s = self.ssm or SSMConfig()
                d_in = s.expand * d
                dt_rank = s.dt_rank or -(-d // 16)
                total += 2 * d * d_in + d_in * s.d_conv
                total += d_in * (dt_rank + 2 * s.d_state)
                total += dt_rank * d_in + d_in + d_in * s.d_state + d_in
                total += d_in * d
            total += self.mlp_params(i, active_only=True)
            total += 2 * d
        return total

    def mlp_params(self, layer_idx: int, active_only: bool = False) -> int:
        d = self.d_model
        if self.is_moe_layer(layer_idx):
            m = self.moe
            e = (m.top_k if active_only else m.num_experts) + m.num_shared
            return e * 3 * d * m.d_ff_expert + d * m.num_experts  # + router
        d_ff = self.d_ff
        if self.moe and self.moe.d_ff_dense and layer_idx < self.moe.first_dense:
            d_ff = self.moe.d_ff_dense
        if d_ff == 0:
            return 0
        return 3 * d * d_ff                                       # swiglu

    def layer_kind(self, i: int) -> str:
        """'attn' | 'mamba' for layer i."""
        if self.attn_pattern == "none":
            return "mamba"
        if self.attn_pattern == "hybrid_1_7":
            # 8-layer blocks, one attention layer per block (position 7)
            return "attn" if (i % 8) == 7 else "mamba"
        return "attn"

    def is_local_layer(self, i: int) -> bool:
        return self.attn_pattern == "local_global" and (i % 2 == 0)

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        if i < self.moe.first_dense:
            return False
        return ((i - self.moe.first_dense) % self.moe.every) == (self.moe.every - 1) \
            if self.moe.every > 1 else True


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> bool:
    """The assignment's skip rule: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k":
        return model.sub_quadratic
    return True
