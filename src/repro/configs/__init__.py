from repro.configs.base import (
    ModelConfig, MoEConfig, SSMConfig, ShapeConfig, SHAPES, shape_applicable,
)
from repro.configs.registry import (
    ASSIGNED_ARCHS, all_cells, get_config, get_shape, get_smoke_config, list_archs,
)

__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "SHAPES",
    "shape_applicable", "ASSIGNED_ARCHS", "all_cells", "get_config",
    "get_shape", "get_smoke_config", "list_archs",
]
