"""qwen2-vl-72b — VLM backbone: GQA decoder with M-RoPE; the vision encoder
is a stub (input_specs supplies precomputed patch embeddings).
[arXiv:2409.12191; hf]"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen2-vl-72b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152_064,
    rope_mode="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    frontend="vision_patches",
    tie_embeddings=False,
    source="arXiv:2409.12191; hf",
)

SMOKE = ModelConfig(
    name=ARCH_ID + "-smoke",
    family="vlm",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=512,
    rope_mode="mrope",
    mrope_sections=(2, 3, 3),
    rope_theta=1_000_000.0,
    frontend="vision_patches",
    tie_embeddings=False,
)
