"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6, first
layer dense. [arXiv:2401.06066; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

ARCH_ID = "deepseek-moe-16b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,               # MHA
    head_dim=128,
    d_ff=1408,                     # expert width (fine-grained)
    vocab_size=102_400,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408, num_shared=2,
                  first_dense=1, d_ff_dense=10944),
    tie_embeddings=False,
    source="arXiv:2401.06066; hf",
)

SMOKE = ModelConfig(
    name=ARCH_ID + "-smoke",
    family="moe",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=32,
    vocab_size=512,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32, num_shared=2,
                  first_dense=1, d_ff_dense=96),
    tie_embeddings=False,
)
